"""Reproduce the paper's YCSB mixed-workload study (Run A/B/C/D/E) — the
read-tail improvement story (§6.3, Fig 12), including the scan-heavy
YCSB-E workload on the typed operation API (PUT/GET/DELETE/SCAN).

    PYTHONPATH=src python examples/ycsb_repro.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.bench_kv import (make_run_a, make_run_b, make_run_c, make_run_d,
                            make_run_e, run_ycsb, sustainable_throughput,
                            make_load_a)
from repro.bench_kv.workloads import load_keys
from repro.core import OpKind, get_policy

SCALE = 1 << 18
N_LOAD, N_RUN = 50_000, 25_000


def main():
    pop = load_keys(N_LOAD)
    # (spec, which OpKind counts as this workload's "read")
    workloads = {
        "run_a(50r/50u)": (make_run_a(pop, N_RUN), OpKind.GET),
        "run_b(95r/5u)": (make_run_b(pop, N_RUN), OpKind.GET),
        "run_c(100r)": (make_run_c(pop, N_RUN), OpKind.GET),
        "run_d(read-latest)": (make_run_d(pop, N_RUN), OpKind.GET),
        "run_e(95scan/5i)": (make_run_e(pop, N_RUN // 5), OpKind.SCAN),
    }
    # Systems resolve from the policy registry by name — swap in any
    # registered policy (e.g. add "lazy" or "adoc") to extend the table.
    systems = {name: get_policy(name).default_config(scale=SCALE)
               for name in ("vlsm", "rocksdb_io", "lazy")}
    header = f"{'workload':20s}" + "".join(
        f" | {s:>10s} W-p99/R-p99 (ms)" for s in systems)
    print(header)
    for wname, (spec, read_op) in workloads.items():
        row = f"{wname:20s}"
        for sname, cfg in systems.items():
            rate = 0.6 * sustainable_throughput(cfg, make_load_a(N_LOAD),
                                                scale=SCALE)
            if read_op == OpKind.SCAN:
                rate = min(rate, 300.0)   # scans are orders pricier per op
            r = run_ycsb(cfg, spec, rate=rate, scale=SCALE, preload=pop)
            row += (f" | {r.sim.pct(99, op=0)*1e3:10.3f}/"
                    f"{r.sim.pct(99, op=int(read_op))*1e3:8.3f}")
        print(row)
    print("\nvLSM's write-stall elimination shows up in READ tails too "
          "(paper: up to 12.5x on Run A reads; run_e extends it to scans).")


if __name__ == "__main__":
    main()

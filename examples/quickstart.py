"""Quickstart: the vLSM KV store reproducing the paper's headline in ~30 s.

Runs YCSB Load A (open-loop, coordinated-omission-free) against RocksDB,
RocksDB-IO, ADOC and vLSM at 60% of each system's sustainable throughput
and prints the tail-latency / stall / chain / amplification comparison.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.bench_kv import make_load_a, run_ycsb, sustainable_throughput
from repro.core import LSMConfig

SCALE = 1 << 18   # data scale: "64 MB" ≙ 256 KiB (device model matched)
N = 60_000


def main():
    spec = make_load_a(N)
    systems = {
        "rocksdb": LSMConfig.rocksdb_default(scale=SCALE),
        "rocksdb-io": LSMConfig.rocksdb_io_default(scale=SCALE),
        "adoc": LSMConfig.adoc_default(scale=SCALE),
        "vlsm": LSMConfig.vlsm_default(scale=SCALE),
    }
    print(f"{'system':11s} {'sus kops':>9s} {'p99 ms':>9s} {'stall max s':>12s} "
          f"{'max chain MB*':>14s} {'io amp':>7s}")
    for name, cfg in systems.items():
        sus = sustainable_throughput(cfg, spec, scale=SCALE)
        r = run_ycsb(cfg, spec, rate=0.6 * sus, scale=SCALE)
        st = r.sim.stats
        print(f"{name:11s} {sus/1e3:9.1f} {r.sim.p99*1e3:9.3f} "
              f"{r.sim.stall_max:12.3f} {st.max_chain_width/1e6*256:14.1f} "
              f"{st.io_amp:7.1f}")
    print("\n* chain widths shown at paper-equivalent scale (x256).")
    print("vLSM: narrow chains -> flat tails; see EXPERIMENTS.md for the "
          "full figure suite.")


if __name__ == "__main__":
    main()

"""End-to-end training driver example: train a (reduced) llama3.2-class
model for a few hundred steps with incremental LSM checkpoints, an
injected node failure mid-run, restore, and loss-curve verification.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=120)
    args = ap.parse_args()
    out = run(args.arch, smoke=True, steps=args.steps, batch=8, seq=64,
              ckpt_every=40, fail_at=args.fail_at, log_every=20)
    losses = np.asarray(out["losses"])
    print(f"\nsteps={len(losses)} restarts={out['restarts']}")
    print(f"loss: first20={losses[:20].mean():.4f} "
          f"last20={losses[-20:].mean():.4f}")
    print(f"checkpoint index (vLSM policy): {out['index_stats']}")
    assert losses[-20:].mean() < losses[:20].mean(), "no learning progress?"
    print("OK: model learned through a failure + restore.")


if __name__ == "__main__":
    main()

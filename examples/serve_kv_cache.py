"""Serving example: batched requests with the LSM-backed prefix cache.

Serves prompts sharing system prefixes through prefill+decode; the prefix
cache (vLSM-indexed page table) turns repeat prefixes into cache hits.

    PYTHONPATH=src python examples/serve_kv_cache.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import run


def main():
    out = run("qwen3_1_7b", smoke=True, n_requests=10, decode_tokens=12)
    s = out["stats"]
    print(f"requests: 10; prefix hits: {s['prefix_hits']}; "
          f"tokens reused: {s['tokens_reused']}")
    print(f"latency p50 {s['p50_ms']:.0f} ms, p99 {s['p99_ms']:.0f} ms")
    print(f"prefix cache: {s['prefix_cache']}")
    assert s["prefix_hits"] >= 4
    print("OK: prefix cache served repeat prefixes from pinned pages.")


if __name__ == "__main__":
    main()

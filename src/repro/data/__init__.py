from .pipeline import BatchAllocator, PipelineState, TokenPipeline

__all__ = ["BatchAllocator", "PipelineState", "TokenPipeline"]

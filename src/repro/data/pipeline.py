"""Deterministic, sharded, resumable token pipeline.

Each data-parallel rank derives its sample stream from (seed, rank, epoch,
cursor) alone, so any rank can recompute any batch — the property both
checkpoint-resume and straggler work-stealing rely on.  The synthetic
corpus is a seeded Markov-ish token generator (benchmark-stable); swap in
a memmap-backed corpus by passing ``corpus=np.ndarray``.

``BatchAllocator`` is the straggler-mitigation hook: batches are claimed
from a global counter, so a slow rank simply claims fewer — nobody waits.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np


@dataclass
class PipelineState:
    seed: int
    rank: int
    world: int
    cursor: int = 0        # batches consumed by this rank
    epoch: int = 0

    def to_dict(self) -> dict:
        return self.__dict__.copy()

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(**d)


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, batch_per_rank: int,
                 state: PipelineState, corpus: np.ndarray | None = None):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_per_rank
        self.state = state
        self.corpus = corpus

    def _batch_rng(self, batch_idx: int) -> np.random.Generator:
        s = self.state
        return np.random.default_rng(
            (s.seed * 1_000_003 + s.epoch) * 7_919
            + batch_idx * s.world + s.rank)

    def make_batch(self, batch_idx: int) -> dict:
        rng = self._batch_rng(batch_idx)
        if self.corpus is not None:
            starts = rng.integers(0, self.corpus.shape[0] - self.seq - 1,
                                  self.batch)
            toks = np.stack([self.corpus[s:s + self.seq + 1] for s in starts])
        else:
            # learnable synthetic stream: next token = (3*tok + noise) % V
            first = rng.integers(0, self.vocab, (self.batch, 1))
            toks = [first]
            for _ in range(self.seq):
                nxt = (3 * toks[-1] + rng.integers(0, 7, (self.batch, 1))) \
                    % self.vocab
                toks.append(nxt)
            toks = np.concatenate(toks, axis=1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def next_batch(self) -> dict:
        b = self.make_batch(self.state.cursor)
        self.state.cursor += 1
        return b


class BatchAllocator:
    """Global work queue for straggler mitigation: ranks claim batch ids."""

    def __init__(self, start: int = 0):
        self._next = start
        self._lock = threading.Lock()
        self.claims: dict[int, list[int]] = {}

    def claim(self, rank: int) -> int:
        with self._lock:
            b = self._next
            self._next += 1
            self.claims.setdefault(rank, []).append(b)
            return b

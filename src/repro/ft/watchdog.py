"""Fault tolerance: step watchdog, failure injection, elastic restart.

``StepWatchdog`` tracks an EMA of step wall-times and flags stragglers
(> ``k``× EMA) — at fleet scale the action is to re-claim that rank's
batches through ``data.BatchAllocator`` and/or trigger an elastic remesh.
``FailureInjector`` drives the restart path in tests/examples: the train
loop catches ``InjectedFailure``, rebuilds a (possibly smaller) mesh, and
restores from the LSM checkpoint store — see launch/train.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


class InjectedFailure(RuntimeError):
    pass


@dataclass
class StepWatchdog:
    threshold: float = 3.0
    alpha: float = 0.2
    ema: float | None = None
    stragglers: list[tuple[int, float]] = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.stragglers.append((step, dt))
        # EMA excludes straggler samples so one hiccup doesn't mask the next
        if not slow:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


@dataclass
class FailureInjector:
    fail_at_step: int | None = None
    fired: bool = False

    def check(self, step: int):
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise InjectedFailure(f"injected node failure at step {step}")

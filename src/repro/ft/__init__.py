from .watchdog import FailureInjector, InjectedFailure, StepWatchdog

__all__ = ["FailureInjector", "InjectedFailure", "StepWatchdog"]

"""Sequence-sharded decode attention ("flash decoding") via shard_map.

Baseline decode for archs whose KV heads don't divide the model axis keeps
the cache sequence-sharded and lets GSPMD all-gather it per layer — the
collective-bound pattern §Roofline exposes.  This module is the optimized
variant: each model shard computes attention over ITS slice of the cache
and the shards combine with a max-rescaled partial softmax:

    m = pmax(m_local);  l = psum(l_local * e^{m_local - m})
    o = psum(o_local * e^{m_local - m}) / l

Wire cost per layer drops from O(B·T·Hkv·D / shards) (gathering the cache)
to O(B·H·D) (three tiny partials) — the decode_32k hillclimb lever.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _local_partial(q, k_loc, v_loc, t0, pos, scale):
    """Partial attention over a local cache slice.

    q: [B, H, Dh]; k_loc/v_loc: [B, T_loc, Hk, Dh]; t0: global index of the
    slice's first token; pos: [B].  Returns (o, l, m) partials.
    """
    b, h, dh = q.shape
    hk = k_loc.shape[2]
    g = h // hk
    qg = q.reshape(b, hk, g, dh)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg.astype(jnp.float32),
                        k_loc.astype(jnp.float32)) * scale
    t_idx = t0 + jnp.arange(k_loc.shape[1])
    mask = t_idx[None, :] <= pos[:, None]                     # [B, T_loc]
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1)                              # [B, Hk, G]
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v_loc.astype(jnp.float32))
    return o, l, m


def seq_sharded_decode_attn(mesh, q, k_cache, v_cache, pos, *,
                            axis: str = "model",
                            scale: float | None = None):
    """q: [B, H, Dh]; caches [B, T, Hk, Dh] sequence-sharded over ``axis``.
    Returns [B, H, Dh] with only O(B·H·Dh) on the wire."""
    b, h, dh = q.shape
    hk = k_cache.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    t_total = k_cache.shape[1]
    n_shards = mesh.shape[axis]
    t_loc = t_total // n_shards

    def body(q, k_loc, v_loc, pos):
        idx = jax.lax.axis_index(axis)
        o, l, m = _local_partial(q, k_loc, v_loc, idx * t_loc, pos, scale)
        m_g = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis)
        o_g = jax.lax.psum(o * corr[..., None], axis)
        out = o_g / jnp.maximum(l_g[..., None], 1e-30)
        return out.reshape(b, h, dh).astype(q.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(None)),
        out_specs=P(None, None, None),
        check_rep=False,
    )(q, k_cache, v_cache, pos)


def reference_decode_attn(q, k_cache, v_cache, pos, *, scale=None):
    """Unsharded oracle for the shard_map combine."""
    b, h, dh = q.shape
    scale = scale if scale is not None else dh ** -0.5
    o, l, m = _local_partial(q, k_cache, v_cache, 0, pos, scale)
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, dh).astype(q.dtype)

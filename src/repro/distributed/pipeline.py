"""GPipe-style pipeline parallelism via shard_map + collective_permute.

TP×DP covers the prescribed 512-chip meshes; pipeline parallelism is the
documented scale-out axis past ~1k chips (DESIGN.md §5).  This module
implements the schedule so the claim is executable, not aspirational:

* the layer stack is split into S contiguous stages, stage s's params
  sharded onto mesh axis "pipe" position s;
* M microbatches stream through; each outer tick every stage processes one
  resident microbatch, then activations ``collective_permute`` one hop
  right.  Fill+drain = S-1 bubble ticks, the standard GPipe efficiency
  M/(M+S-1);
* the body is a single jitted shard_map — no host round-trips per tick.

Tested with 8 forced host devices (tests/test_pipeline.py subprocess) by
comparing against the unpipelined stack.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(mesh, stage_fn, stacked_params, x, *, n_micro: int,
                   axis: str = "pipe"):
    """Run ``stage_fn(params_slice, h) -> h`` over S pipeline stages.

    stacked_params: pytree with leading dim S (sharded over ``axis``).
    x: [M, mb, ...] microbatched input (replicated).  Returns [M, mb, ...].
    """
    n_stages = mesh.shape[axis]
    assert n_micro == x.shape[0]

    def body(params_loc, x_all):
        params_loc = jax.tree.map(lambda a: a[0], params_loc)  # this stage
        stage = jax.lax.axis_index(axis)
        mb_shape = x_all.shape[1:]
        n_ticks = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            acc, inflight = carry
            # which microbatch does stage 0 inject this tick?
            inject = jnp.where(t < n_micro, t, 0)
            h_in = jnp.where(stage == 0, x_all[inject], inflight)
            h_out = stage_fn(params_loc, h_in)
            # last stage retires microbatch (t - (S-1)) when valid
            retire_idx = t - (n_stages - 1)
            valid = (retire_idx >= 0) & (stage == n_stages - 1)
            acc = jax.lax.cond(
                valid,
                lambda a: jax.lax.dynamic_update_index_in_dim(
                    a, h_out, jnp.maximum(retire_idx, 0), 0),
                lambda a: a, acc)
            inflight = jax.lax.ppermute(h_out, axis, perm)
            return (acc, inflight), None

        acc0 = jnp.zeros((n_micro,) + mb_shape, x_all.dtype)
        inflight0 = jnp.zeros(mb_shape, x_all.dtype)
        (acc, _), _ = jax.lax.scan(tick, (acc0, inflight0),
                                   jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        acc = jax.lax.psum(
            jnp.where(stage == n_stages - 1, acc, jnp.zeros_like(acc)), axis)
        return acc

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )(stacked_params, x)


def unpipelined_reference(stage_fn, stacked_params, x):
    """Oracle: sequential application of all stages to all microbatches."""
    n_stages = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]

    def apply_all(h):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stacked_params)
            h = stage_fn(p, h)
        return h

    return jax.vmap(apply_all)(x)

"""Sharding rules: param/optimizer/input/cache PartitionSpecs per arch.

Mesh contract (launch/mesh.py): ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod.  Batch shards over
``("pod", "data")`` (pure DP across pods — pods only ever see
batch-parallel collectives, keeping the slow inter-pod links off the TP
critical path); tensor parallelism lives on the 16-wide intra-pod "model"
axis (Megatron column->row pairs, EP for MoE experts, vocab-parallel
embeddings).

Where a config's head counts don't divide the model axis (gemma3's 4
heads, whisper's 6, llama3.2's 24) GSPMD compiles anyway via padded
shardings — the §Roofline table then shows the resharding cost explicitly,
and §Perf hillclimbs pick better layouts for the cells where it dominates.
SSM mixer weights are replicated (state heads rarely divide 16; the mixers
are small), with the "model" axis still carrying MLP/attention TP in the
hybrid and vocab TP everywhere.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _spec_for_param(cfg, path: tuple[str, ...], ndim: int) -> P:
    name = path[-1]
    stacked = path[0] == "layers"   # leading scan dim

    def pad(spec_dims: tuple) -> P:
        missing = ndim - len(spec_dims)
        return P(*([None] * missing + list(spec_dims)))

    col = ("model",)
    # --- embeddings / head ------------------------------------------------
    if name == "embed":
        return pad((
            "model", None))
    if name == "lm_head":
        return pad((None, "model"))
    # --- SSD mixer: replicate (see module docstring) -----------------------
    if "ssd" in path:
        return P(*([None] * ndim))
    # --- attention ---------------------------------------------------------
    import os
    if os.environ.get("REPRO_ATTN_REPLICATED") == "1" and name in (
            "wq", "wk", "wv", "wo"):
        # §Perf variant: replicate attention weights (small for GQA archs
        # whose head counts don't divide the model axis) so activations
        # never reshard around the head split.
        return P(*([None] * ndim))
    if name in ("wq", "wk", "wv", "w_uk", "w_uv"):
        return pad((None, "model"))
    if name == "wo":
        return pad(("model", None))
    if name in ("w_dkv", "w_kr"):
        return P(*([None] * ndim))
    # --- MLP ----------------------------------------------------------------
    if name in ("w_gate", "w_up", "s_gate", "s_up"):
        return pad((None, "model"))
    if name in ("w_down", "s_down"):
        return pad(("model", None))
    if name == "b_up":
        return pad(("model",))
    if name == "b_down":
        return P(*([None] * ndim))
    # --- MoE: expert-parallel over the model axis ---------------------------
    if name in ("e_gate", "e_up", "e_down"):
        return pad(("model", None, None))
    if name == "router":
        return P(*([None] * ndim))
    del stacked, col
    # norms, biases, scalars: replicate
    return P(*([None] * ndim))


def param_specs(cfg, params_shape) -> dict:
    """PartitionSpec pytree matching a params (shape) pytree."""
    def fn(path, leaf):
        names = tuple(p.key for p in path)
        return _spec_for_param(cfg, names, len(leaf.shape))
    return jax.tree_util.tree_map_with_path(fn, params_shape)


def zero1_specs(cfg, params_shape, mesh) -> dict:
    """Optimizer-moment specs: param spec + ZeRO-1 'data' sharding folded
    onto the largest still-unsharded divisible axis."""
    data = mesh.shape.get("data", 1)

    def fn(path, leaf):
        names = tuple(p.key for p in path)
        spec = list(_spec_for_param(cfg, names, len(leaf.shape)))
        best, best_dim = None, 0
        for i, (s, d) in enumerate(zip(spec, leaf.shape)):
            if s is None and d % data == 0 and d > best_dim:
                best, best_dim = i, d
        if best is not None and best_dim >= data:
            spec[best] = "data"
        return P(*spec)
    return jax.tree_util.tree_map_with_path(fn, params_shape)


def train_batch_specs(cfg, mesh) -> dict:
    import os
    ba = batch_axes(mesh)
    # §Perf variant: sequence/context parallelism — shard the sequence dim
    # over 'model' so activations stay distributed through the stack (the
    # attention K/V all-gather is tiny next to resharded activations).
    seq = "model" if os.environ.get("REPRO_SEQ_SHARD") == "1" else None
    specs = {"tokens": P(ba, seq), "labels": P(ba, seq)}
    if cfg.family == "encdec":
        specs["encoder_embeds"] = P(ba, None, None)
    if cfg.mrope_sections:
        specs["positions"] = P(ba, seq, None)
    return specs


def cache_specs(cfg, mesh, *, batch1: bool = False) -> dict:
    """Decode-cache specs.

    Normal decode: batch shards over the batch axes; KV heads shard over
    'model' when divisible, otherwise the *sequence* dim does (the
    always-fits baseline; GSPMD all-gathers per layer during attention —
    the flash-decode shard_map in distributed/flash_decode.py is the
    optimized variant).

    ``batch1`` (long_500k): the batch dim is unshardable, so the sequence
    dim takes the data axes (plus 'model' when heads can't use it) — a
    half-million-token cache spreads over all 256/512 chips.
    """
    da = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    ba = None if batch1 else batch_axes(mesh)
    heads_ok = (cfg.n_kv_heads or 1) % mesh.shape.get("model", 1) == 0
    if batch1:
        seq = da + (() if heads_ok else ("model",))
        hd = "model" if heads_ok else None
    else:
        seq = None if heads_ok else "model"
        hd = "model" if heads_ok else None
    ssm_heads_ok = (cfg.ssm_heads % mesh.shape.get("model", 1) == 0
                    if cfg.ssm_state else False)
    sh = "model" if ssm_heads_ok else None
    if cfg.family in ("ssm", "hybrid"):
        specs = {
            "conv": P(None, ba, None, None),
            "state": P(None, ba, sh, None, None),
            "pos": P(None),
        }
        if cfg.attn_every:
            specs["attn_k"] = P(None, ba, seq, hd, None)
            specs["attn_v"] = P(None, ba, seq, hd, None)
        return specs
    if cfg.family == "encdec":
        return {
            "k": P(None, ba, seq, hd, None),
            "v": P(None, ba, seq, hd, None),
            "cross_k": P(None, ba, None, hd, None),
            "cross_v": P(None, ba, None, hd, None),
            "pos": P(None),
        }
    if cfg.attn_kind == "mla":
        mseq = (da + ("model",)) if batch1 else "model"
        specs = {
            "ckv": P(None, ba, mseq, None),
            "kr": P(None, ba, mseq, None),
            "pos": P(None),
        }
        if cfg.first_dense_layers:
            specs["d_ckv"] = P(None, ba, mseq, None)
            specs["d_kr"] = P(None, ba, mseq, None)
        return specs
    return {
        "k": P(None, ba, seq, hd, None),
        "v": P(None, ba, seq, hd, None),
        "pos": P(None),
    }


def decode_input_specs(cfg, mesh) -> dict:
    ba = batch_axes(mesh)
    return {"tokens": P(ba, None), "pos": P(ba)}


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

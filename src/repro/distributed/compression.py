"""int8 gradient compression with error feedback.

``compressed_psum`` quantizes a tensor to int8 with a per-tensor scale,
all-reduces the int8 payload (4x fewer wire bytes than f32, 2x fewer than
bf16), and dequantizes.  ``compress_tree`` applies it with **error
feedback**: the quantization residual is carried in ``opt_state['ef']`` and
added back next step, which keeps SGD-style convergence (1-bit Adam
lineage).  The train_step factory enables it with ``compress_grads=True``
for the cross-pod reduction — the slow-link hop of the multi-pod mesh.

benchmarks/compression_wire.py lowers both variants and diffs the parsed
collective bytes (the dry-run methodology applied to one op).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Mean over ``axis`` with int8 on the wire (inside shard_map).

    Formulated as an int8 all-gather + local dequant-sum (the 1-bit-Adam
    family's transport): an fp32 ring psum moves ~2x fp32 bytes per
    device, the int8 gather moves (G-1)/G x int8 bytes — an ~8x/G-adjusted
    wire reduction that benchmarks/compression_wire.py verifies from the
    compiled HLO.  Per-shard scales ride along (negligible) and make the
    dequant exact per contributor.
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis)                  # [G, ...] int8 wire
    ss = jax.lax.all_gather(scale, axis)              # [G] f32 (tiny)
    n = qs.shape[0]
    ss = ss.reshape((n,) + (1,) * x.ndim)
    return jnp.sum(qs.astype(jnp.float32) * ss, axis=0) / n


def compress_tree(grads, opt_state):
    """Quantize every gradient leaf to int8 with error feedback.

    Residuals live in opt_state['ef'] (created on first use).  In-pod
    reductions already happened inside backward; this models the payload
    handed to the cross-pod reduction.
    """
    ef = opt_state.get("ef")
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_ef = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_state = dict(opt_state)
    new_state["ef"] = new_ef
    return new_g, new_state


def cross_pod_mean_compressed(mesh, tree):
    """Explicit int8 cross-pod gradient mean (shard_map over 'pod')."""
    def body(flat):
        return [compressed_psum(x, "pod") for x in flat]
    flat, tdef = jax.tree.flatten(tree)
    specs = tuple(P() for _ in flat)
    out = shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs,
                    check_rep=False)(flat)
    return jax.tree.unflatten(tdef, out)

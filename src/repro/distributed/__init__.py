from .sharding import (batch_axes, cache_specs, decode_input_specs,
                       param_specs, to_shardings, train_batch_specs,
                       zero1_specs)

__all__ = ["batch_axes", "cache_specs", "decode_input_specs", "param_specs",
           "to_shardings", "train_batch_specs", "zero1_specs"]

"""Model configuration: one dataclass superset covering all 10 assigned
architectures (dense GQA, MLA+MoE, SSM, hybrid, enc-dec, VLM backbone)."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # 'decoder' | 'encdec' | 'ssm' | 'hybrid'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # ---- attention ----
    attn_kind: str = "gqa"          # 'gqa' | 'mla'
    qk_norm: bool = False           # qwen3
    rope_theta: float = 1e4
    rope_theta_global: float | None = None   # gemma3 global layers
    window: int | None = None       # sliding-window size for local layers
    global_every: int = 0           # gemma3: every k-th layer is global
    mrope_sections: tuple[int, ...] = ()     # qwen2-vl M-RoPE half-dim split
    use_rope: bool = True           # whisper uses absolute sinusoidal

    # ---- MLA (deepseek-v2) ----
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # ---- MLP / MoE ----
    mlp_kind: str = "swiglu"        # 'swiglu' | 'gelu' | 'moe'
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0
    dense_d_ff: int = 0

    # ---- SSM (mamba2 / zamba2) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_kernel: int = 4
    expand: int = 2
    attn_every: int = 0             # zamba2: shared attn block cadence

    # ---- enc-dec (whisper backbone) ----
    enc_layers: int = 0
    enc_seq: int = 1500

    # ---- misc ----
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    post_norm: bool = False         # gemma3 sandwich norms
    norm_type: str = "rmsnorm"      # 'rmsnorm' | 'layernorm'
    param_dtype: str = "bfloat16"
    sub_quadratic: bool = False     # eligible for long_500k decode

    # -------------------------------------------------------------- derived
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        if self.attn_kind == "mla":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    def layer_windows(self) -> list[int]:
        """Per-layer attention window; -1 means global (full causal)."""
        out = []
        for i in range(self.n_layers):
            if self.window is None:
                out.append(-1)
            elif self.global_every and (i + 1) % self.global_every == 0:
                out.append(-1)
            else:
                out.append(self.window)
        return out

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm", "hybrid"):
            di, ns = self.d_inner, self.ssm_state
            g = self.ssm_groups
            conv_dim = di + 2 * g * ns
            per = (d * (2 * di + 2 * g * ns + self.ssm_heads)  # in_proj
                   + conv_dim * self.conv_kernel               # conv
                   + di * d                                    # out_proj
                   + di + 2 * self.ssm_heads)                  # norm, A, D
            total = self.n_layers * per
            if self.attn_every:
                h = self.n_heads * self.head_dim
                total += (d * h * 4 + d * self.d_ff * 3)       # shared block
            return total + emb
        if self.attn_kind == "mla":
            attn = (d * self.q_dim                             # W_q
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            h, hk = self.n_heads * self.head_dim, self.n_kv_heads * self.head_dim
            attn = d * (h + 2 * hk) + h * d
        if self.mlp_kind == "moe":
            moe = 3 * d * self.d_ff * (self.n_experts + self.n_shared_experts)
            moe += d * self.n_experts
            dense_layers = self.first_dense_layers
            mlp_total = ((self.n_layers - dense_layers) * moe
                         + dense_layers * 3 * d * self.dense_d_ff)
            mlp = 0
        else:
            act = self.dense_d_ff or self.d_ff
            del act
            mlp = (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
            mlp_total = self.n_layers * mlp
        total = self.n_layers * attn + mlp_total + emb
        if self.family == "encdec":
            enc_attn = d * (self.n_heads * self.head_dim) * 4
            enc_mlp = 2 * d * self.d_ff
            cross = d * (self.n_heads * self.head_dim) * 4
            total += self.enc_layers * (enc_attn + enc_mlp)
            total += self.n_layers * cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.mlp_kind != "moe":
            return self.param_count()
        d = self.d_model
        full_moe = 3 * d * self.d_ff * (self.n_experts + self.n_shared_experts)
        active_moe = 3 * d * self.d_ff * (self.top_k + self.n_shared_experts)
        n_moe_layers = self.n_layers - self.first_dense_layers
        return self.param_count() - n_moe_layers * (full_moe - active_moe)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if not self.attn_every else 7),
            d_model=128, d_ff=256, vocab_size=512,
            n_heads=max(2, min(4, self.n_heads)),
            head_dim=64,
            param_dtype="float32",
        )
        kw["n_kv_heads"] = min(self.n_kv_heads, kw["n_heads"])
        if self.attn_kind == "mla":
            kw.update(kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                      v_head_dim=32)
        if self.mrope_sections:
            half = kw["head_dim"] // 2
            kw["mrope_sections"] = (half // 4, half // 4, half // 2)
        if self.mlp_kind == "moe":
            # capacity_factor 4.0: drop-free at smoke batch sizes, so the
            # prefill->decode parity tests are exact (production keeps 1.25)
            kw.update(n_experts=4, top_k=2, n_shared_experts=1,
                      first_dense_layers=min(1, self.first_dense_layers),
                      dense_d_ff=256, capacity_factor=4.0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=32, d_model=128)
            if self.attn_every:
                kw.update(attn_every=3)
        if self.family == "encdec":
            kw.update(enc_layers=2, enc_seq=32)
        if self.global_every:
            kw.update(window=16, global_every=2)
        return self.with_(**kw)


# shapes assigned to the LM pool (seq_len, global_batch, kind)
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

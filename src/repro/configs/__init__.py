from .base import SHAPES, ModelConfig, ShapeSpec
from .registry import ARCH_IDS, all_configs, get_config

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeSpec", "all_configs",
           "get_config"]

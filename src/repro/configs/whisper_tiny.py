"""whisper-tiny [audio]: enc-dec transformer backbone, conv frontend STUB.

[arXiv:2212.04356; unverified] 4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865.  The audio frontend (2x conv + GELU) is stubbed per the
assignment: input_specs() feeds precomputed 1500-frame encoder embeddings.
Decoder uses absolute sinusoidal positions (no RoPE); full attention, so
long_500k is skipped (see DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    mlp_kind="gelu", norm_type="layernorm", use_rope=False,
    enc_layers=4, enc_seq=1500, tie_embeddings=True,
    sub_quadratic=False,
)

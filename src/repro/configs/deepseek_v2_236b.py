"""deepseek-v2-236b [moe]: [arXiv:2405.04434; hf]
60L d_model=5120 128H, MLA kv_lora=512, MoE: 160 routed experts top-6 +
2 shared, expert d_ff=1536, first layer dense (d_ff 12288), vocab=102400."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="decoder",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab_size=102400,
    attn_kind="mla", kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    mlp_kind="moe", n_experts=160, n_shared_experts=2, top_k=6,
    first_dense_layers=1, dense_d_ff=12288,
    rope_theta=10000.0, tie_embeddings=False, sub_quadratic=False,
)

"""yi-6b [dense]: [arXiv:2403.04652; hf] llama-arch GQA
32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="decoder",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000, rope_theta=5000000.0,
    tie_embeddings=False, sub_quadratic=False,
)

"""Architecture registry: the 10 assigned configs, exactly as specified.

Sources are public ([hf:...] / [arXiv:...] per the assignment); each file
``configs/<id>.py`` exposes ``CONFIG``.
"""

from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeSpec  # noqa: F401

ARCH_IDS = [
    "whisper_tiny",
    "llama3_2_3b",
    "gemma3_1b",
    "yi_6b",
    "qwen3_1_7b",
    "qwen2_vl_2b",
    "zamba2_1_2b",
    "deepseek_v2_lite",
    "deepseek_v2_236b",
    "mamba2_130m",
]

_ALIASES = {
    "whisper-tiny": "whisper_tiny",
    "llama3.2-3b": "llama3_2_3b",
    "gemma3-1b": "gemma3_1b",
    "yi-6b": "yi_6b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-1.2b": "zamba2_1_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-130m": "mamba2_130m",
}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

"""zamba2-1.2b [hybrid]: [arXiv:2411.15242; hf] Mamba2 backbone + SHARED
attention block cadence.  38L d_model=2048, shared attn 32H (kv=32,
head_dim 64), d_ff=8192 (shared block MLP), vocab=32000, ssm_state=64.
Simplification noted in DESIGN.md: the shared transformer block (one
weight set reused every 6 mamba layers) runs on the residual stream
directly (Zamba's concat-with-embedding + per-use LoRA is omitted).
State-space backbone -> eligible for long_500k decode."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_groups=1, expand=2, conv_kernel=4,
    attn_every=6, tie_embeddings=True, sub_quadratic=True,
)

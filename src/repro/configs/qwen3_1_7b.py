"""qwen3-1.7b [dense]: [hf:Qwen/Qwen3-1.7B; hf] qk_norm, GQA
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="decoder",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936, rope_theta=1000000.0,
    qk_norm=True, tie_embeddings=True, sub_quadratic=False,
)

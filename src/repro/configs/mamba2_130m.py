"""mamba2-130m [ssm]: [arXiv:2405.21060; unverified] SSD (state-space
duality).  24L d_model=768 (attn-free) vocab=50280, ssm_state=128,
expand=2 (d_inner 1536, 24 heads of P=64).  O(1)-state decode ->
eligible for long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_groups=1, expand=2, conv_kernel=4,
    tie_embeddings=True, sub_quadratic=True,
)

"""gemma3-1b [dense]: [hf:google/gemma-3-1b-pt; unverified]
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
5:1 local:global attention (512-token sliding window locally), dual RoPE
theta (10k local / 1M global), sandwich (pre+post) RMSNorm, tied embeddings.
Sliding-window dominated -> eligible for long_500k decode (the 1-in-6
global layers still attend the full cache; decode remains O(n)/step)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="decoder",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    window=512, global_every=6, rope_theta=10000.0,
    rope_theta_global=1000000.0, post_norm=True,
    tie_embeddings=True, sub_quadratic=True,
)

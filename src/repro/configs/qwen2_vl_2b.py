"""qwen2-vl-2b [vlm]: [arXiv:2409.12191; hf] M-RoPE, dynamic resolution.
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
BACKBONE ONLY per the assignment: the ViT frontend is a STUB —
input_specs() feeds precomputed patch embeddings; M-RoPE runs with its
(16, 24, 24) temporal/height/width half-dim sections on stub positions."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="decoder",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True, sub_quadratic=False,
)

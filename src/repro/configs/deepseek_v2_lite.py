"""deepseek-v2-lite-16b [moe]: [arXiv:2405.04434; hf]
27L d_model=2048 16H, MLA kv_lora=512 (qk_nope 128 + qk_rope 64, v 128),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer
dense (d_ff 10944), vocab=102400."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="decoder",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    attn_kind="mla", kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    mlp_kind="moe", n_experts=64, n_shared_experts=2, top_k=6,
    first_dense_layers=1, dense_d_ff=10944,
    rope_theta=10000.0, tie_embeddings=False, sub_quadratic=False,
)

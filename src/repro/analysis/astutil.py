"""Shared AST plumbing: module loading, import resolution, docstring and
suppression-comment bookkeeping.

Everything here is pure stdlib on purpose — the analyzer must be
importable (and runnable in CI) without the simulation stack, and the
import-graph rule itself requires this package to stay leaf-like.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path


@dataclass
class Module:
    """One parsed source file under the analysis root."""

    path: Path                 # absolute
    rel: str                   # posix path relative to the root
    name: str                  # dotted module name ("repro.core.sim")
    is_package: bool           # True for __init__.py
    tree: ast.Module
    lines: list[str]           # source lines (1-based access via line(n))
    doc_lines: set[int] = field(default_factory=set)

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def suppressed(self, lineno: int, rule: str) -> bool:
        """Inline suppression: ``# lint-ok`` or ``# lint-ok: D203,L104``
        on the offending line."""
        text = self.line(lineno)
        if "# lint-ok" not in text:
            return False
        tag = text.split("# lint-ok", 1)[1].strip()
        if not tag.startswith(":"):
            return True                      # bare `# lint-ok`: all rules
        listed = {r.strip() for r in tag[1:].split(",")}
        return rule in listed


def module_name(rel: str) -> tuple[str, bool]:
    """Dotted module name for a root-relative posix path.

    A leading ``src/`` is dropped (the repo uses a src layout and the
    fixture trees replicate it), so ``src/repro/core/sim.py`` →
    ``repro.core.sim``; ``__init__.py`` names its package.
    """
    parts = rel.split("/")
    if parts[0] == "src":
        parts = parts[1:]
    assert parts and parts[-1].endswith(".py")
    parts[-1] = parts[-1][:-3]
    is_package = parts[-1] == "__init__"
    if is_package:
        parts = parts[:-1]
    return ".".join(parts), is_package


def iter_py_files(root: Path, paths: list[Path]) -> list[Path]:
    files: set[Path] = set()
    for p in paths:
        p = p if p.is_absolute() else root / p
        if p.is_dir():
            files.update(f for f in p.rglob("*.py"))
        elif p.suffix == ".py":
            files.add(p)
    return sorted(files)


def load_modules(root: Path, paths: list[Path]) -> list[Module]:
    modules = []
    for f in iter_py_files(root, paths):
        rel = f.relative_to(root).as_posix()
        source = f.read_text()
        tree = ast.parse(source, filename=str(f))
        name, is_package = module_name(rel)
        mod = Module(path=f, rel=rel, name=name, is_package=is_package,
                     tree=tree, lines=source.splitlines())
        mod.doc_lines = docstring_lines(tree)
        modules.append(mod)
    return modules


def docstring_lines(tree: ast.Module) -> set[int]:
    """Line numbers covered by module/class/function docstrings."""
    covered: set[int] = set()
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
            continue
        body = getattr(node, "body", [])
        if (body and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)):
            doc = body[0].value
            covered.update(range(doc.lineno, (doc.end_lineno or doc.lineno)
                                 + 1))
    return covered


def dotted(node: ast.AST) -> str | None:
    """Render ``a.b.c`` attribute chains; None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_type_checking_guard(test: ast.AST) -> bool:
    name = dotted(test)
    return name in ("TYPE_CHECKING", "typing.TYPE_CHECKING")


@dataclass(frozen=True)
class ImportEdge:
    """One import statement target, resolved to a dotted module path.

    For ``from X import a, b`` the edge target is ``X`` and ``names``
    carries ``(a, b)`` — callers who care whether ``X.a`` is itself a
    module resolve that against the scanned-module set.
    """

    target: str
    lineno: int
    names: tuple[str, ...] = ()
    top_level: bool = True


def resolve_relative(mod: Module, level: int, suffix: str | None) -> str:
    """Resolve a ``from ...X import Y`` target to an absolute dotted path."""
    parts = mod.name.split(".")
    # parent package of the importing module:
    pkg = parts if mod.is_package else parts[:-1]
    drop = level - 1
    base = pkg[:len(pkg) - drop] if drop else pkg
    if suffix:
        base = base + suffix.split(".")
    return ".".join(base)


def import_edges(mod: Module, include_nested: bool = False
                 ) -> list[ImportEdge]:
    """Import targets of a module.

    By default only *top-level* imports count (the ones that execute at
    import time and can create cycles): statements in the module body,
    descending through ``if``/``try`` but skipping ``if TYPE_CHECKING:``
    bodies.  With ``include_nested`` every import anywhere in the file is
    returned (used by the "never import X" rules, where hiding the
    import inside a function is still a violation).
    """
    edges: list[ImportEdge] = []

    def visit(stmts, top: bool) -> None:
        for st in stmts:
            if isinstance(st, ast.Import):
                for alias in st.names:
                    edges.append(ImportEdge(alias.name, st.lineno,
                                            top_level=top))
            elif isinstance(st, ast.ImportFrom):
                if st.module is None and st.level == 0:
                    continue
                if st.level:
                    target = resolve_relative(mod, st.level, st.module)
                else:
                    target = st.module
                edges.append(ImportEdge(
                    target, st.lineno,
                    tuple(a.name for a in st.names), top_level=top))
            elif isinstance(st, ast.If):
                if _is_type_checking_guard(st.test):
                    if include_nested:
                        visit(st.body, False)
                else:
                    visit(st.body, top)
                visit(st.orelse, top)
            elif isinstance(st, ast.Try):
                visit(st.body, top)
                for h in st.handlers:
                    visit(h.body, top)
                visit(st.orelse, top)
                visit(st.finalbody, top)
            elif isinstance(st, (ast.With, ast.For, ast.While)):
                visit(st.body, top)
                visit(getattr(st, "orelse", []), top)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                if include_nested:
                    visit(st.body, False)
    visit(mod.tree.body, True)
    return edges if include_nested else [e for e in edges if e.top_level]

"""CLI: ``python -m repro.analysis [--format json] [paths...]``.

Exit status: 0 when every finding is baselined (or none), 1 when fresh
findings exist, 2 on usage errors.  See ``docs/analysis.md``.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import (DEFAULT_BASELINE_NAME, FAMILIES, analyze_paths,
                     find_repo_root, split_baselined)
from .findings import load_baseline, write_baseline


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: architecture/determinism analysis "
                    "over the repo's AST and import graph")
    parser.add_argument("paths", nargs="*",
                        help="files/directories to analyze, relative to "
                             "--root (default: src/repro)")
    parser.add_argument("--root", type=Path, default=None,
                        help="analysis root (default: the enclosing "
                             "repo)")
    parser.add_argument("--format", choices=("text", "json", "github"),
                        default="text",
                        help="'github' emits ::error annotations for "
                             "GitHub Actions")
    parser.add_argument("--explain", metavar="RULE", default=None,
                        help="print the catalog entry for one rule id "
                             "(e.g. U501) and exit")
    parser.add_argument("--rules", default=None, metavar="FAM[,FAM...]",
                        help=f"rule families to run (default: all of "
                             f"{', '.join(FAMILIES)})")
    parser.add_argument("--baseline", type=Path, default=None,
                        help=f"baseline file (default: "
                             f"<root>/{DEFAULT_BASELINE_NAME})")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report baselined findings too")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept all current findings into the "
                             "baseline file and exit 0")
    parser.add_argument("--write-contract-table", action="store_true",
                        help="regenerate the contract table in "
                             "core/policies/base.py, then exit")
    parser.add_argument("--write-schema-table", action="store_true",
                        help="regenerate the bench-schema table in "
                             "docs/benchmarks.md, then exit")
    args = parser.parse_args(argv)

    if args.explain:
        from .catalog import CATALOG, explain
        text = explain(args.explain)
        if text is None:
            parser.error(f"unknown rule {args.explain!r} (registered: "
                         f"{', '.join(sorted(CATALOG))})")
        print(text)
        return 0

    root = (args.root or find_repo_root()).resolve()
    if args.write_contract_table:
        from .contracts import write_contract_table
        base_path = root / "src/repro/core/policies/base.py"
        if not base_path.exists():
            parser.error(f"no base.py under {root}")
        changed = write_contract_table(base_path)
        print(f"{base_path}: "
              + ("contract table rewritten" if changed
                 else "contract table already up to date"))
        return 0
    if args.write_schema_table:
        from .schemas import DOC_REL, write_schema_table
        doc_path = root / DOC_REL
        if not doc_path.exists():
            parser.error(f"no {DOC_REL} under {root}")
        changed = write_schema_table(root)
        print(f"{doc_path}: "
              + ("schema table rewritten" if changed
                 else "schema table already up to date"))
        return 0

    families = None
    if args.rules:
        families = tuple(f.strip() for f in args.rules.split(",")
                         if f.strip())
    paths = [root / p for p in args.paths] if args.paths else None
    try:
        findings = analyze_paths(root, paths, families)
    except ValueError as e:
        parser.error(str(e))

    baseline_path = args.baseline or (root / DEFAULT_BASELINE_NAME)
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"{baseline_path}: {len(findings)} finding(s) baselined")
        return 0

    baseline = load_baseline(baseline_path)
    fresh, known = split_baselined(findings, baseline)
    shown = findings if args.no_baseline else fresh

    if args.format == "github":
        # GitHub Actions workflow annotations: one ::error per fresh
        # finding, anchored at the file/line the web UI will show
        for f in fresh:
            msg = f"{f.rule} {f.message} (hint: {f.hint})"
            msg = msg.replace("%", "%25").replace("\r", "%0D") \
                     .replace("\n", "%0A")
            print(f"::error file={f.path},line={f.line},"
                  f"title=repro-lint {f.rule}::{msg}")
        print(("FAIL: " if fresh else "OK: ")
              + f"{len(fresh)} finding(s)"
              + (f" ({len(known)} baselined)" if known else ""))
    elif args.format == "json":
        print(json.dumps({
            "root": str(root),
            "families": list(families or FAMILIES),
            "fresh": [f.to_dict() for f in fresh],
            "baselined": [f.to_dict() for f in known],
            "exit": 1 if fresh else 0,
        }, indent=2))
    else:
        for f in shown:
            print(f.format())
        tail = f"{len(fresh)} finding(s)"
        if known:
            tail += f" ({len(known)} baselined)"
        print(("FAIL: " if fresh else "OK: ") + tail)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())

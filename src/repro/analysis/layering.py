"""Layering rules (L1xx): the mechanism/policy split, mechanically.

The split (PR 3) is load-bearing: the parity gates assume the engine's
behaviour is a pure function of ``(cfg, policy object)``, so the engine
must never special-case a policy, and a policy must never reach past the
contract surface ``base.py`` declares.  These rules replace the old
``grep``-based purity test.

Rules:

* **L101** — ``core/lsm.py`` / ``sim.py`` / ``fleet.py`` import a
  concrete policy module (anything under ``repro.core.policies`` other
  than the package itself, whose registry is the sanctioned entry).
* **L102** — a mechanism file branches on a policy identity: a string
  constant equal to a registered policy name outside a docstring, or a
  ``Policy.<member>`` legacy-enum access.
* **L103** — a policy calls a tree/index method outside the contract
  surface (``MECHANISM_PRIMITIVES`` / ``INDEX_QUERIES`` in ``base.py``).
* **L104** — a policy mutates engine structure directly
  (``tree.levels`` / ``tree.index`` / tree attributes), outside the two
  shared L0 bodies in ``base.py`` that own L0 by contract.
* **L105** — ``kernels/*`` imports ``repro.core`` (kernels are the
  bottom layer; the engine calls them, never the reverse).
* **L106** — the top-level import graph has a cycle.
"""

from __future__ import annotations

import ast

from .astutil import Module, dotted, import_edges
from .findings import Finding

FAMILY = "layering"

MECH_RELS = ("src/repro/core/lsm.py", "src/repro/core/sim.py",
             "src/repro/core/fleet.py")
POLICY_PKG = "repro.core.policies"
POLICY_DIR = "src/repro/core/policies/"
KERNELS_DIR = "src/repro/kernels/"
CORE_PKG = "repro.core"

#: counter ledgers a policy may bump freely (``tree.stats.x += 1``)
_STATS_ATTRS = ("stats",)
#: the two shared L0 strategy bodies in ``base.py`` that own L0
L0_BODIES = ("_tiering_l0", "_incremental_l0")


def _finding(rule: str, mod: Module, lineno: int, message: str,
             hint: str) -> Finding:
    return Finding(rule=rule, family=FAMILY, path=mod.rel, line=lineno,
                   message=message, hint=hint,
                   snippet=mod.line(lineno))


# --------------------------------------------------------------------------
# contract surface, parsed from base.py (single source for rule + table)

class ContractSurface:
    """The tree/index API policies may use, as declared in ``base.py``."""

    def __init__(self, primitives: tuple[str, ...],
                 index_queries: tuple[str, ...],
                 l0_index_mutators: tuple[str, ...]):
        self.primitives = primitives
        self.index_queries = index_queries
        self.l0_index_mutators = l0_index_mutators


def parse_contract_surface(base_mod: Module) -> ContractSurface | None:
    tuples: dict[str, tuple[str, ...]] = {}
    for node in base_mod.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            name = node.targets[0].id
            if name in ("MECHANISM_PRIMITIVES", "INDEX_QUERIES",
                        "L0_INDEX_MUTATORS"):
                try:
                    value = ast.literal_eval(node.value)
                except ValueError:
                    continue
                tuples[name] = tuple(value)
    if "MECHANISM_PRIMITIVES" not in tuples:
        return None
    return ContractSurface(tuples["MECHANISM_PRIMITIVES"],
                           tuples.get("INDEX_QUERIES", ()),
                           tuples.get("L0_INDEX_MUTATORS", ()))


def registered_policy_names(policy_mods: list[Module]) -> set[str]:
    """Policy registry keys, read statically: every ``name = "..."``
    class attribute on a class in the policies package."""
    names: set[str] = set()
    for mod in policy_mods:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for st in node.body:
                if (isinstance(st, ast.Assign) and len(st.targets) == 1
                        and isinstance(st.targets[0], ast.Name)
                        and st.targets[0].id == "name"
                        and isinstance(st.value, ast.Constant)
                        and isinstance(st.value.value, str)
                        and st.value.value):
                    names.add(st.value.value)
    return names


# --------------------------------------------------------------------------
# L101 / L102: the mechanism must not know the policies

def check_mechanism(mech_mods: list[Module], scanned: set[str],
                    policy_names: set[str]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in mech_mods:
        for edge in import_edges(mod, include_nested=True):
            targets = [edge.target]
            # `from pkg import x` imports module pkg.x when x is one
            targets += [f"{edge.target}.{n}" for n in edge.names
                        if f"{edge.target}.{n}" in scanned]
            for t in targets:
                if t.startswith(POLICY_PKG + ".") and t != POLICY_PKG:
                    findings.append(_finding(
                        "L101", mod, edge.lineno,
                        f"mechanism file imports concrete policy module "
                        f"{t!r}",
                        "resolve policies only through the registry "
                        "(`from .policies import get_policy`)"))
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Constant)
                    and isinstance(node.value, str)
                    and node.value in policy_names
                    and node.lineno not in mod.doc_lines):
                findings.append(_finding(
                    "L102", mod, node.lineno,
                    f"mechanism file references policy name "
                    f"{node.value!r}",
                    "the engine must be policy-agnostic: route the "
                    "decision through a CompactionPolicy hook"))
            elif (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "Policy"):
                findings.append(_finding(
                    "L102", mod, node.lineno,
                    f"mechanism file branches on legacy Policy enum "
                    f"(Policy.{node.attr})",
                    "replace the enum branch with a CompactionPolicy "
                    "hook"))
    return findings


# --------------------------------------------------------------------------
# L103 / L104: policies stay behind the contract surface

_MUTATING_LIST_METHODS = ("append", "clear", "extend", "insert", "pop",
                          "remove", "reverse", "sort")


def _tree_params(fn: ast.FunctionDef) -> set[str]:
    """Parameter names that carry the live LSMTree."""
    names: set[str] = set()
    for arg in (list(fn.args.posonlyargs) + list(fn.args.args)
                + list(fn.args.kwonlyargs)):
        ann = arg.annotation
        ann_s = ""
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            ann_s = ann.value
        elif ann is not None:
            ann_s = ast.unparse(ann)
        if arg.arg == "tree" or "LSMTree" in ann_s:
            names.add(arg.arg)
    return names


def _root_of(node: ast.AST) -> ast.AST:
    """Peel Attribute/Subscript chains down to their base expression."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node


def check_policy_purity(policy_mods: list[Module],
                        surface: ContractSurface) -> list[Finding]:
    findings: list[Finding] = []
    for mod in policy_mods:
        in_base = mod.rel.endswith("/base.py")
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            trees = _tree_params(fn)
            if not trees:
                continue
            l0_body = in_base and fn.name in L0_BODIES
            findings.extend(_check_policy_fn(mod, fn, trees, surface,
                                             l0_body))
    return findings


def _check_policy_fn(mod: Module, fn: ast.FunctionDef, trees: set[str],
                     surface: ContractSurface,
                     l0_body: bool) -> list[Finding]:
    findings: list[Finding] = []
    # aliases of engine-owned structure (`l0 = tree.levels[0]`)
    aliases: set[str] = set()

    def is_tree(node: ast.AST) -> bool:
        return isinstance(node, ast.Name) and node.id in trees

    def is_tree_attr(node: ast.AST, attr: str) -> bool:
        return (isinstance(node, ast.Attribute) and node.attr == attr
                and is_tree(node.value))

    def structural_expr(node: ast.AST) -> bool:
        """Does this expression reach into tree.levels / tree.index?"""
        root = _root_of(node)
        if isinstance(root, ast.Name) and root.id in aliases:
            return True
        probe = node
        while isinstance(probe, (ast.Attribute, ast.Subscript)):
            if is_tree_attr(probe, "levels") or is_tree_attr(probe,
                                                             "index"):
                return True
            probe = probe.value
        return False

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            # record structure aliases before judging the targets
            if (len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and structural_expr(node.value)):
                aliases.add(node.targets[0].id)
        if isinstance(node, ast.Call):
            func = node.func
            if not isinstance(func, ast.Attribute):
                continue
            owner = func.value
            if is_tree(owner):
                if func.attr not in surface.primitives:
                    findings.append(_finding(
                        "L103", mod, node.lineno,
                        f"policy calls non-contract tree method "
                        f"tree.{func.attr}()",
                        "use only the mechanism primitives listed in "
                        "base.py's contract table, or extend the "
                        "contract deliberately"))
            elif is_tree_attr(owner, "index"):
                if func.attr in surface.index_queries:
                    pass
                elif func.attr in surface.l0_index_mutators and l0_body:
                    pass
                elif func.attr in surface.l0_index_mutators:
                    findings.append(_finding(
                        "L104", mod, node.lineno,
                        f"policy mutates the LevelIndex "
                        f"(tree.index.{func.attr}()) outside the shared "
                        f"L0 bodies",
                        "L0 index ownership belongs to base.py's "
                        "_tiering_l0/_incremental_l0 only"))
                else:
                    findings.append(_finding(
                        "L103", mod, node.lineno,
                        f"policy calls non-contract index method "
                        f"tree.index.{func.attr}()",
                        "only the read-only INDEX_QUERIES in base.py's "
                        "contract table are policy-visible"))
            elif (isinstance(owner, ast.Name) and owner.id in aliases
                    and func.attr in _MUTATING_LIST_METHODS
                    and not l0_body):
                findings.append(_finding(
                    "L104", mod, node.lineno,
                    f"policy mutates engine structure through alias "
                    f"{owner.id!r} ({owner.id}.{func.attr}())",
                    "structure changes must go through the mechanism "
                    "primitives (merge_down/replace_in_level/...)"))
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target] if isinstance(node, ast.AugAssign)
                       else node.targets)
            for tgt in targets:
                if isinstance(tgt, ast.Name):
                    continue      # rebinding a local is never a mutation
                # counters on the stats ledger are fair game
                probe = tgt
                while isinstance(probe, (ast.Attribute, ast.Subscript)):
                    if (isinstance(probe, ast.Attribute)
                            and probe.attr in _STATS_ATTRS
                            and is_tree(probe.value)):
                        break
                    probe = probe.value
                else:
                    probe = None
                if probe is not None:
                    continue
                direct_attr = (isinstance(tgt, ast.Attribute)
                               and is_tree(tgt.value))
                if (structural_expr(tgt) or direct_attr) and not l0_body:
                    findings.append(_finding(
                        "L104", mod, node.lineno,
                        "policy writes engine structure directly "
                        f"({ast.unparse(tgt)})",
                        "mutate only through the mechanism primitives; "
                        "L0 ownership lives in base.py's shared L0 "
                        "bodies"))
    return findings


# --------------------------------------------------------------------------
# L105: kernels never import core

def check_kernels(kernel_mods: list[Module]) -> list[Finding]:
    findings = []
    for mod in kernel_mods:
        for edge in import_edges(mod, include_nested=True):
            if (edge.target == CORE_PKG
                    or edge.target.startswith(CORE_PKG + ".")):
                findings.append(_finding(
                    "L105", mod, edge.lineno,
                    f"kernel module imports {edge.target!r}",
                    "kernels are the bottom layer: hoist shared types "
                    "out of core, or pass plain arrays in"))
    return findings


# --------------------------------------------------------------------------
# L106: acyclic import graph

def check_import_cycles(modules: list[Module]) -> list[Finding]:
    by_name = {m.name: m for m in modules}
    graph: dict[str, set[str]] = {m.name: set() for m in modules}
    edge_line: dict[tuple[str, str], int] = {}
    for mod in modules:
        for edge in import_edges(mod):
            targets = []
            if edge.target in by_name:
                targets.append(edge.target)
            targets += [f"{edge.target}.{n}" for n in edge.names
                        if f"{edge.target}.{n}" in by_name]
            for t in targets:
                if t == mod.name:
                    continue
                # an ancestor package is always mid-initialization when
                # a submodule imports from it (`from . import x`) — the
                # interpreter tolerates that, so it is not a cycle edge;
                # the resolved submodule targets still are.
                if mod.name.startswith(t + "."):
                    continue
                graph[mod.name].add(t)
                edge_line.setdefault((mod.name, t), edge.lineno)

    sccs = _tarjan(graph)
    findings = []
    for scc in sccs:
        if len(scc) < 2:
            continue
        members = sorted(scc)
        anchor = by_name[members[0]]
        in_scc = set(scc)
        lineno = min((edge_line[(members[0], t)]
                      for t in graph[members[0]] if t in in_scc),
                     default=1)
        findings.append(_finding(
            "L106", anchor, lineno,
            "import cycle: " + " -> ".join(members + [members[0]]),
            "break the cycle with a TYPE_CHECKING-only import, a "
            "function-scoped import, or by moving the shared type down "
            "a layer"))
    return findings


def _tarjan(graph: dict[str, set[str]]) -> list[list[str]]:
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: dict[str, bool] = {}
    stack: list[str] = []
    sccs: list[list[str]] = []
    counter = [0]

    def strongconnect(v: str) -> None:
        # iterative Tarjan: (node, iterator) frames
        work = [(v, iter(sorted(graph[v])))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack[v] = True
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack[w] = True
                    work.append((w, iter(sorted(graph[w]))))
                    advanced = True
                    break
                elif on_stack.get(w):
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    scc.append(w)
                    if w == node:
                        break
                sccs.append(scc)
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])

    for v in sorted(graph):
        if v not in index:
            strongconnect(v)
    return sccs


# --------------------------------------------------------------------------

def check(modules: list[Module]) -> list[Finding]:
    """Run every layering rule over one scanned module set."""
    scanned = {m.name for m in modules}
    mech = [m for m in modules if m.rel in MECH_RELS]
    policies = [m for m in modules if m.rel.startswith(POLICY_DIR)]
    kernels = [m for m in modules if m.rel.startswith(KERNELS_DIR)]
    base = next((m for m in policies if m.rel.endswith("/base.py")), None)

    findings: list[Finding] = []
    policy_names = registered_policy_names(policies)
    findings += check_mechanism(mech, scanned, policy_names)
    if base is not None:
        surface = parse_contract_surface(base)
        if surface is not None:
            findings += check_policy_purity(policies, surface)
    findings += check_kernels(kernels)
    findings += check_import_cycles(modules)
    return findings

"""The machine-readable rule catalog: every registered rule id with its
family, fires-when description, and fix hint.

Three consumers keep this honest:

* ``python -m repro.analysis --explain RULE`` prints an entry;
* ``scripts/check_links.py`` diffs the ids against the rule tables in
  ``docs/analysis.md`` (doc/catalog drift fails CI like a broken link);
* ``tests/test_analysis.py`` asserts every *static* rule has a fixture.

The S4xx sanitizer rules are runtime invariants (no fixture marker, no
baseline fingerprints) but they are registered here so ``--explain``
and the doc check cover them too.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Rule:
    id: str
    family: str
    fires_when: str
    hint: str
    runtime: bool = False      # S4xx: enforced by the DES sanitizer


_R = Rule

CATALOG: dict[str, Rule] = {r.id: r for r in (
    # -- layering (L1xx) ---------------------------------------------------
    _R("L101", "layering",
       "a mechanism file imports a concrete policy module",
       "route through the registry (get_policy)"),
    _R("L102", "layering",
       "a mechanism file branches on a policy name string or Policy "
       "enum member",
       "add a hook to CompactionPolicy instead"),
    _R("L103", "layering",
       "a policy calls a tree/index method outside the contract surface",
       "use a MECHANISM_PRIMITIVES / INDEX_QUERIES entry, or widen the "
       "contract in base.py"),
    _R("L104", "layering",
       "a policy mutates engine structure directly",
       "mutate via replace_in_level / the shared _tiering_l0 / "
       "_incremental_l0 bodies"),
    _R("L105", "layering",
       "repro.kernels imports repro.core",
       "kernels are the substrate; pass arrays in, keep the dependency "
       "one-way"),
    _R("L106", "layering",
       "an import cycle among repro modules",
       "break the cycle (e.g. a leaf module with no repro imports)"),
    # -- determinism (D2xx) ------------------------------------------------
    _R("D201", "determinism",
       "wall clock in logic (time.time, datetime.now, ...)",
       "derive names/ids from counters or seeds; time.perf_counter is "
       "fine for measuring wall time"),
    _R("D202", "determinism",
       "global RNG (np.random.rand, random.random, ...)",
       "thread an explicit np.random.default_rng(seed)"),
    _R("D203", "determinism",
       "ordered iteration over a set/frozenset",
       "iterate sorted(s)"),
    _R("D204", "determinism",
       "sorted/min/max/.sort with key=id",
       "sort by a stable field (uid, name)"),
    _R("D205", "determinism",
       "sum/functools.reduce over a set (float addition is not "
       "associative)",
       "sum(sorted(s)) or accumulate in insertion order"),
    # -- contracts (C3xx) --------------------------------------------------
    _R("C301", "contracts",
       "an override's signature is incompatible with the "
       "CompactionPolicy hook (base args must be a prefix; extras need "
       "defaults)",
       "match the base hook; add keyword defaults for policy-specific "
       "knobs"),
    _R("C302", "contracts",
       "a policy class grows a public method that is not a base hook",
       "prefix with '_', or promote it to a base hook"),
    _R("C303", "contracts",
       "a registered policy is missing name or a default_config "
       "override",
       "every registry entry must be constructible from "
       "default_config(scale)"),
    _R("C304", "contracts",
       "the generated contract table in base.py's docstring is stale",
       "python -m repro.analysis --write-contract-table"),
    # -- sanitizer (S4xx, runtime) -----------------------------------------
    _R("S401", "sanitizer",
       "per-tree event times decrease during a run (REPRO_SANITIZE=1)",
       "event heap corruption: audit the push site the traceback names",
       runtime=True),
    _R("S402", "sanitizer",
       "a chain child starts before its parent_job finishes",
       "audit chain dependency wiring (deps / parent_job)",
       runtime=True),
    _R("S403", "sanitizer",
       "overlapping occupancy of a (tree, level) compaction slot",
       "audit SlotPool.schedule bookkeeping for that level",
       runtime=True),
    _R("S404", "sanitizer",
       "stall-gate queries per tree go back in time",
       "audit the stall-gate pruning order",
       runtime=True),
    # -- units (U5xx) ------------------------------------------------------
    _R("U501", "units",
       "+/-/comparison mixes two known units (seconds vs ms, bytes vs "
       "MB, ...)",
       "convert one side explicitly (* 1e3 for s→ms, / 1e6 for "
       "bytes→MB) before combining"),
    _R("U502", "units",
       "an assignment/return/dict entry whose target name carries a "
       "unit suffix receives a different known unit with no conversion "
       "factor",
       "apply the conversion at the site (* 1e3, / 1e6, round(x * 1e3, "
       "...)) or rename the target"),
    _R("U503", "units",
       "a conversion factor is applied to an already-converted value "
       "(ms * 1e3, MB / 1e6)",
       "the value is already in the target unit; drop the factor"),
    _R("U504", "units",
       "an unsuffixed key in a bench-row dict carries a value with a "
       "known dimension",
       "suffix the key (_s, _ms, _bytes, _mb, _ops_s) so JSON "
       "consumers know the unit"),
    # -- schemas (B6xx) ----------------------------------------------------
    _R("B601", "schemas",
       "the generated schema table in docs/benchmarks.md is stale or "
       "missing",
       "python -m repro.analysis --write-schema-table"),
    _R("B602", "schemas",
       "BENCH_dbbench.json disagrees with the emitter dict literals "
       "(missing/extra/mistyped keys, orphan families)",
       "regenerate the JSON (python -m repro.bench_kv.db_bench --json "
       "BENCH_dbbench.json) or fix the emitter"),
    _R("B603", "schemas",
       "the same key name carries two different units in two bench "
       "families",
       "one key name, one unit: rename one side or convert"),
)}

#: rules with `# expect-lint` fixtures (everything the AST pass emits)
STATIC_RULES: tuple[str, ...] = tuple(
    r.id for r in CATALOG.values() if not r.runtime)
RUNTIME_RULES: tuple[str, ...] = tuple(
    r.id for r in CATALOG.values() if r.runtime)


def explain(rule_id: str) -> str | None:
    """The --explain text for one rule id (None when unregistered)."""
    r = CATALOG.get(rule_id.upper())
    if r is None:
        return None
    kind = "runtime invariant" if r.runtime else "static rule"
    return (f"{r.id} [{r.family}] ({kind})\n"
            f"  fires when: {r.fires_when}\n"
            f"  fix hint:   {r.hint}")

"""Rule dispatch: load modules under a root, run the rule families,
apply inline suppressions and the baseline.

The root is configurable (``--root``) so the lint fixtures — a
miniature tree replicating the ``src/repro`` layout under
``tests/data/lint_fixtures/`` — exercise every rule against a fake
"repo" with the exact same path-scoping logic the real one gets.
"""

from __future__ import annotations

from pathlib import Path

from . import contracts, determinism, layering, schemas, units
from .astutil import Module, load_modules
from .findings import Baseline, Finding

FAMILIES = ("layering", "determinism", "contracts", "units", "schemas")
DEFAULT_BASELINE_NAME = ".repro-lint-baseline.json"
#: default analysis scope under the root
DEFAULT_PATHS = ("src/repro",)


def find_repo_root(start: Path | None = None) -> Path:
    """Nearest ancestor holding a ``pyproject.toml`` or ``.git`` —
    starting from this package (editable installs put it inside the
    repo), falling back to the working directory."""
    candidates = [Path(__file__).resolve(), (start or Path.cwd()).resolve()]
    for origin in candidates:
        node = origin if origin.is_dir() else origin.parent
        while True:
            if (node / "pyproject.toml").exists() or (node / ".git").exists():
                return node
            if node.parent == node:
                break
            node = node.parent
    return Path.cwd()


def analyze_paths(root: Path, paths: list[Path] | None = None,
                  families: tuple[str, ...] | None = None
                  ) -> list[Finding]:
    """Run the selected rule families over ``paths`` (default:
    ``src/repro``) relative to ``root``.  Returns findings sorted by
    location; inline ``# lint-ok`` suppressions already applied, the
    baseline NOT applied (callers decide)."""
    root = Path(root).resolve()
    if paths is None:
        paths = [root / p for p in DEFAULT_PATHS if (root / p).exists()] \
            or [root]
    families = tuple(families or FAMILIES)
    unknown = set(families) - set(FAMILIES)
    if unknown:
        raise ValueError(f"unknown rule families: {sorted(unknown)} "
                         f"(choose from {FAMILIES})")
    modules = load_modules(root, [Path(p) for p in paths])
    by_rel = {m.rel: m for m in modules}

    findings: list[Finding] = []
    if "layering" in families:
        findings += layering.check(modules)
    if "determinism" in families:
        findings += determinism.check(modules)
    if "contracts" in families:
        findings += contracts.check(
            [m for m in modules
             if m.rel.startswith(layering.POLICY_DIR)])
    if "units" in families:
        findings += units.check(modules)
    if "schemas" in families:
        # root-scoped: diffs the fixed emitter/doc/JSON inputs below
        # the root regardless of the selected paths
        findings += schemas.check(root)

    findings = [f for f in findings
                if not _suppressed(by_rel, f)]
    return sorted(set(findings), key=lambda f: (f.path, f.line, f.rule))


def _suppressed(by_rel: dict[str, Module], f: Finding) -> bool:
    mod = by_rel.get(f.path)
    return mod is not None and mod.suppressed(f.line, f.rule)


def analyze_repo(families: tuple[str, ...] | None = None,
                 root: Path | None = None,
                 apply_baseline: bool = True) -> list[Finding]:
    """Analyze the repo this package lives in; the entry point tests
    use (``tests/test_policies.py`` calls the layering family here)."""
    from .findings import load_baseline
    root = Path(root) if root else find_repo_root()
    findings = analyze_paths(root, families=families)
    if apply_baseline:
        baseline = load_baseline(root / DEFAULT_BASELINE_NAME)
        findings = [f for f in findings if not baseline.covers(f)]
    return findings


def split_baselined(findings: list[Finding], baseline: Baseline
                    ) -> tuple[list[Finding], list[Finding]]:
    fresh = [f for f in findings if not baseline.covers(f)]
    known = [f for f in findings if baseline.covers(f)]
    return fresh, known

"""Determinism rules (D2xx): the hazards bit-identical replay dies on.

The fleet-vs-serial parity gate and the byte-identical read-replay
captures only hold if every run of the same op stream takes the same
path.  These rules flag the classic ways Python code silently stops
being a pure function of its inputs:

* **D201** — wall-clock reads: ``time.time`` / ``time.time_ns`` /
  ``datetime.now`` / ``utcnow`` / ``today``.  ``time.perf_counter`` is
  allowed by convention, but only for *measure-and-report* timing
  (``wall_clock_s``) that never feeds back into simulated state.
* **D202** — process-global RNG: bare ``random.*`` and legacy
  ``np.random.*`` (anything that is not the explicit-``Generator`` API:
  ``default_rng`` / ``SeedSequence`` / ``Generator`` / bit generators).
* **D203** — ordered iteration over a ``set`` (``for``/comprehension/
  ``list()``/``tuple()``/``enumerate()``/``iter()``/``.join()`` over a
  set expression or a same-scope set alias).  Order-insensitive
  consumers (``sorted``, ``len``, ``min``, ``max``, ``any``, ``all``,
  set algebra, membership) are fine.
* **D204** — identity-keyed ordering: ``sorted``/``min``/``max``/
  ``.sort`` with ``key=id`` (ids vary run to run).
* **D205** — float reduction over an unordered container: ``sum()`` /
  ``functools.reduce`` over a set source (float addition is not
  associative; ``math.fsum`` is exempt because its result is
  order-independent).
"""

from __future__ import annotations

import ast

from .astutil import Module, dotted
from .findings import Finding

FAMILY = "determinism"

_WALL_CLOCK = {
    "time.time", "time.time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "SFC64", "MT19937", "BitGenerator"}
_ORDER_SENSITIVE_WRAPPERS = {"list", "tuple", "enumerate", "iter"}


def _finding(rule: str, mod: Module, lineno: int, message: str,
             hint: str) -> Finding:
    return Finding(rule=rule, family=FAMILY, path=mod.rel, line=lineno,
                   message=message, hint=hint, snippet=mod.line(lineno))


def _import_aliases(tree: ast.Module) -> dict[str, str]:
    """Map local names to the dotted path they are bound to, for every
    import anywhere in the module (``np`` → ``numpy``, a from-imported
    ``time`` → ``time.time``, ...)."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _resolve(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Dotted path of a call target with its root import-alias expanded."""
    path = dotted(node)
    if path is None:
        return None
    head, _, rest = path.partition(".")
    full_head = aliases.get(head, head)
    return f"{full_head}.{rest}" if rest else full_head


class _SetTracker:
    """Set-valued expressions and their same-scope name aliases."""

    def __init__(self) -> None:
        self.names: set[str] = set()

    def is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in ("set", "frozenset")):
            return True
        if (isinstance(node, ast.BinOp)
                and isinstance(node.op, (ast.BitAnd, ast.BitOr, ast.Sub,
                                         ast.BitXor))
                and (self.is_set_expr(node.left)
                     or self.is_set_expr(node.right))):
            return True          # set algebra stays a set
        return (isinstance(node, ast.Name) and node.id in self.names)

    def note_assign(self, node: ast.Assign) -> None:
        if (len(node.targets) == 1 and isinstance(node.targets[0], ast.Name)):
            if self.is_set_expr(node.value):
                self.names.add(node.targets[0].id)
            else:
                self.names.discard(node.targets[0].id)


def check(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules:
        findings.extend(_check_module(mod))
    return findings


def _check_module(mod: Module) -> list[Finding]:
    findings: list[Finding] = []
    aliases = _import_aliases(mod.tree)
    sets = _SetTracker()

    def flag(rule, node, message, hint):
        findings.append(_finding(rule, mod, node.lineno, message, hint))

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign):
            sets.note_assign(node)
        if isinstance(node, ast.Call):
            target = _resolve(node.func, aliases)
            if target in _WALL_CLOCK:
                flag("D201", node,
                     f"wall-clock read ({target}())",
                     "wall time breaks replay determinism: use a "
                     "monotonic counter for identity, time.perf_counter "
                     "for measure-only timing")
            elif target and target.startswith("random."):
                flag("D202", node,
                     f"process-global RNG ({target}())",
                     "draw from an explicitly seeded "
                     "np.random.default_rng(seed) passed in by the "
                     "caller")
            elif (target and target.startswith("numpy.random.")
                    and target.split(".")[2] not in _NP_RANDOM_OK):
                flag("D202", node,
                     f"legacy global numpy RNG ({target}())",
                     "use the Generator API: "
                     "np.random.default_rng(seed)")
            # D203: order-sensitive wrappers over a set
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _ORDER_SENSITIVE_WRAPPERS
                    and node.args and sets.is_set_expr(node.args[0])):
                flag("D203", node,
                     f"{node.func.id}() over a set fixes an arbitrary "
                     f"order",
                     "sort first (sorted(s)) or keep an ordered "
                     "container")
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "join"
                    and node.args and sets.is_set_expr(node.args[0])):
                flag("D203", node,
                     "str.join over a set fixes an arbitrary order",
                     "join sorted(s) instead")
            # D204: identity-keyed ordering
            fn_name = (node.func.id if isinstance(node.func, ast.Name)
                       else node.func.attr
                       if isinstance(node.func, ast.Attribute) else "")
            if fn_name in ("sorted", "min", "max", "sort"):
                for kw in node.keywords:
                    if (kw.arg == "key" and isinstance(kw.value, ast.Name)
                            and kw.value.id == "id"):
                        flag("D204", node,
                             f"{fn_name}(..., key=id) orders by object "
                             f"identity",
                             "object ids vary per run: key on a stable "
                             "field (uid, name, tuple)")
            # D205: float reduction over an unordered source
            if isinstance(node.func, ast.Name) and node.func.id == "sum" \
                    and node.args:
                src = node.args[0]
                unordered = sets.is_set_expr(src)
                if isinstance(src, (ast.GeneratorExp, ast.ListComp)) \
                        and src.generators:
                    unordered = sets.is_set_expr(src.generators[0].iter)
                if unordered:
                    flag("D205", node,
                         "sum() over a set: float addition order is "
                         "unspecified",
                         "sum over sorted(s) (or use math.fsum, which "
                         "is order-independent)")
            if (_resolve(node.func, aliases) == "functools.reduce"
                    and len(node.args) >= 2
                    and sets.is_set_expr(node.args[1])):
                flag("D205", node,
                     "functools.reduce over a set: fold order is "
                     "unspecified",
                     "reduce over sorted(s)")
        elif isinstance(node, ast.For) and sets.is_set_expr(node.iter):
            flag("D203", node,
                 "for-loop over a set iterates in arbitrary order",
                 "iterate sorted(s), or restructure so order cannot "
                 "matter")
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                               ast.DictComp)):
            for gen in node.generators:
                if sets.is_set_expr(gen.iter):
                    flag("D203", node,
                         "comprehension over a set produces an "
                         "arbitrary order",
                         "iterate sorted(s) (a SetComp result would be "
                         "fine; ordered outputs are not)")
    return findings

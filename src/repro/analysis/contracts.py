"""Policy-contract rules (C3xx) and the generated contract table.

``CompactionPolicy`` is duck-typed: the engine calls hooks by name with
positional arguments, so a misspelt override or a drifted signature is a
*silent* behaviour change (the base default runs instead).  These rules
make the contract load-bearing:

* **C301** — an override's signature is incompatible with the base
  hook's (the engine calls positionally: the base's parameter names
  must survive as a prefix, and any extra parameters need defaults).
* **C302** — a public method on a policy class is not part of the hook
  set (almost always a typo'd override; helpers belong under a leading
  underscore).
* **C303** — a registered policy misses a required member: the
  ``default_config`` override or a non-empty ``name`` literal.
* **C304** — the contract table in ``base.py``'s class docstring does
  not match the hooks/primitives actually declared (regenerate with
  ``python -m repro.analysis --write-contract-table``).

The table generator lives here too, so the checker and the generator
cannot disagree.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path

from .astutil import Module
from .findings import Finding
from .layering import parse_contract_surface

FAMILY = "contracts"

BASE_CLASS = "CompactionPolicy"
TABLE_START = ".. contract-table-start"
TABLE_END = ".. contract-table-end"

#: class attributes (not hooks) a policy may override
_ATTR_OVERRIDES = ("name", "tiering_l0", "soft_limit_factor")
_REQUIRED_HOOKS = ("default_config",)


def _finding(rule: str, mod: Module, lineno: int, message: str,
             hint: str) -> Finding:
    return Finding(rule=rule, family=FAMILY, path=mod.rel, line=lineno,
                   message=message, hint=hint, snippet=mod.line(lineno))


# --------------------------------------------------------------------------
# base.py introspection

@dataclass
class Hook:
    name: str
    args: tuple[str, ...]      # positional parameter names, minus self
    has_vararg: bool
    has_kwarg: bool
    defaults: int              # how many trailing args have defaults
    required: bool             # body is `raise NotImplementedError`
    lineno: int

    def signature(self) -> str:
        parts = list(self.args)
        if self.has_vararg:
            parts.append("*args")
        if self.has_kwarg:
            parts.append("**kw")
        return f"{self.name}({', '.join(parts)})"


def _hook_of(fn: ast.FunctionDef) -> Hook:
    args = tuple(a.arg for a in (list(fn.args.posonlyargs)
                                 + list(fn.args.args)))
    if args and args[0] == "self":
        args = args[1:]
    body = [st for st in fn.body
            if not (isinstance(st, ast.Expr)
                    and isinstance(st.value, ast.Constant))]
    required = (len(body) == 1 and isinstance(body[0], ast.Raise)
                and "NotImplementedError" in ast.dump(body[0]))
    return Hook(name=fn.name, args=args,
                has_vararg=fn.args.vararg is not None,
                has_kwarg=fn.args.kwarg is not None,
                defaults=len(fn.args.defaults), required=required,
                lineno=fn.lineno)


def base_hooks(base_mod: Module) -> dict[str, Hook]:
    cls = _class_def(base_mod, BASE_CLASS)
    if cls is None:
        return {}
    return {st.name: _hook_of(st) for st in cls.body
            if isinstance(st, ast.FunctionDef)
            and not st.name.startswith("__")}


def _class_def(mod: Module, name: str) -> ast.ClassDef | None:
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


# --------------------------------------------------------------------------
# policy classes and the registry

@dataclass
class PolicyClass:
    mod: Module
    node: ast.ClassDef
    bases: tuple[str, ...]


def _policy_classes(policy_mods: list[Module]) -> dict[str, PolicyClass]:
    """Every class in the policies package that descends (transitively,
    within the package) from ``CompactionPolicy``."""
    all_classes: dict[str, PolicyClass] = {}
    for mod in policy_mods:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                bases = tuple(b.id for b in node.bases
                              if isinstance(b, ast.Name))
                all_classes[node.name] = PolicyClass(mod, node, bases)

    def descends(name: str, seen: frozenset = frozenset()) -> bool:
        if name == BASE_CLASS:
            return True
        pc = all_classes.get(name)
        if pc is None or name in seen:
            return False
        return any(descends(b, seen | {name}) for b in pc.bases)

    return {n: pc for n, pc in all_classes.items()
            if n != BASE_CLASS and descends(n)}


def _registered_class_names(policy_mods: list[Module]) -> dict[str, Module]:
    """Class names passed to ``register(Cls())`` / ``registry.register``."""
    registered: dict[str, Module] = {}
    for mod in policy_mods:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = (node.func.id if isinstance(node.func, ast.Name)
                     else node.func.attr
                     if isinstance(node.func, ast.Attribute) else "")
            if fname != "register" or not node.args:
                continue
            arg = node.args[0]
            if (isinstance(arg, ast.Call)
                    and isinstance(arg.func, ast.Name)):
                registered[arg.func.id] = mod
    return registered


def _mro_chain(name: str, classes: dict[str, PolicyClass]) -> list[PolicyClass]:
    chain: list[PolicyClass] = []
    seen: set[str] = set()
    frontier = [name]
    while frontier:
        cur = frontier.pop(0)
        if cur in seen or cur not in classes:
            continue
        seen.add(cur)
        chain.append(classes[cur])
        frontier.extend(classes[cur].bases)
    return chain


# --------------------------------------------------------------------------
# the rules

def _check_signature(hook: Hook, override: Hook) -> str | None:
    """C301 core: why is ``override`` incompatible with ``hook``?"""
    base_args = hook.args
    ov_args = override.args
    if override.has_vararg and base_args[:len(ov_args)] == ov_args:
        return None
    if ov_args[:len(base_args)] != base_args:
        if len(ov_args) < len(base_args) and not override.has_vararg:
            return (f"drops base parameters: base takes "
                    f"({', '.join(base_args)})")
        return (f"renames/reorders base parameters: base takes "
                f"({', '.join(base_args)}), override takes "
                f"({', '.join(ov_args)})")
    extras = ov_args[len(base_args):]
    undefaulted = len(ov_args) - override.defaults
    bad = [a for i, a in enumerate(ov_args)
           if a in extras and i < undefaulted]
    if bad:
        return (f"extra parameter(s) without defaults: "
                f"{', '.join(bad)} (the engine calls hooks "
                f"positionally with the base arity)")
    return None


def check(policy_mods: list[Module]) -> list[Finding]:
    base_mod = next((m for m in policy_mods
                     if m.rel.endswith("/base.py")), None)
    if base_mod is None:
        return []
    hooks = base_hooks(base_mod)
    classes = _policy_classes(policy_mods)
    registered = _registered_class_names(policy_mods)
    findings: list[Finding] = []

    for cname in sorted(classes):
        pc = classes[cname]
        for st in pc.node.body:
            if not isinstance(st, ast.FunctionDef) \
                    or st.name.startswith("__"):
                continue
            override = _hook_of(st)
            hook = hooks.get(st.name)
            if hook is not None:
                why = _check_signature(hook, override)
                if why:
                    findings.append(_finding(
                        "C301", pc.mod, st.lineno,
                        f"{cname}.{st.name} signature incompatible "
                        f"with the base hook: {why}",
                        f"match base: {hook.signature()}"))
            elif not st.name.startswith("_"):
                findings.append(_finding(
                    "C302", pc.mod, st.lineno,
                    f"{cname}.{st.name} is not a CompactionPolicy "
                    f"hook",
                    "typo'd override? prefix private helpers with "
                    "'_'; extend the contract in base.py if this is "
                    "a new hook"))

    for cname in sorted(registered):
        if cname not in classes:
            continue
        chain = _mro_chain(cname, classes)
        mod = registered[cname]
        lineno = classes[cname].node.lineno
        has_required = {h: False for h in _REQUIRED_HOOKS}
        has_name = False
        for pc in chain:
            for st in pc.node.body:
                if isinstance(st, ast.FunctionDef) \
                        and st.name in has_required:
                    has_required[st.name] = True
                if (isinstance(st, ast.Assign)
                        and len(st.targets) == 1
                        and isinstance(st.targets[0], ast.Name)
                        and st.targets[0].id == "name"
                        and isinstance(st.value, ast.Constant)
                        and st.value.value):
                    has_name = True
        for h, ok in has_required.items():
            if not ok:
                findings.append(_finding(
                    "C303", mod, lineno,
                    f"registered policy {cname} never overrides "
                    f"required hook {h}()",
                    f"implement {h}() (the base raises "
                    f"NotImplementedError)"))
        if not has_name:
            findings.append(_finding(
                "C303", mod, lineno,
                f"registered policy {cname} has no non-empty `name` "
                f"class attribute",
                "the registry keys policies by `name`"))

    findings += check_contract_table(base_mod)
    return findings


# --------------------------------------------------------------------------
# C304: the generated contract table

def generate_contract_table(base_mod: Module, indent: str = "    ") -> str:
    """Render the contract table from ``base.py``'s actual declarations.

    Deterministic text; both the C304 check and
    ``--write-contract-table`` call this, so they cannot drift.
    """
    hooks = base_hooks(base_mod)
    surface = parse_contract_surface(base_mod)
    lines: list[str] = [TABLE_START, ""]
    lines.append("Hook surface (generated; regenerate with "
                 "``python -m repro.analysis --write-contract-table``):")
    lines.append("")
    public = [h for n, h in hooks.items() if not n.startswith("_")]
    shared = [h for n, h in hooks.items() if n.startswith("_")]
    width = max((len(h.signature()) for h in public + shared), default=0)
    for h in sorted(public, key=lambda h: h.lineno):
        kind = "required" if h.required else "default provided"
        lines.append(f"{h.signature():<{width}}  [{kind}]")
    for h in sorted(shared, key=lambda h: h.lineno):
        lines.append(f"{h.signature():<{width}}  [shared L0 body]")
    if surface is not None:
        lines.append("")
        lines.append("mechanism primitives (the only tree mutators "
                     "policies may call):")
        lines.append("  " + ", ".join(surface.primitives))
        lines.append("read-only index queries:")
        lines.append("  " + ", ".join(surface.index_queries))
        lines.append("index mutators owned by the shared L0 bodies:")
        lines.append("  " + ", ".join(surface.l0_index_mutators))
    lines.append("")
    lines.append(TABLE_END)
    return "\n".join(indent + ln if ln else "" for ln in lines)


def _current_table_block(source: str) -> tuple[str, int] | None:
    """The table text currently in the file and its start line (1-based)."""
    lines = source.splitlines()
    start = end = None
    for i, ln in enumerate(lines):
        if TABLE_START in ln and start is None:
            start = i
        elif TABLE_END in ln and start is not None:
            end = i
            break
    if start is None or end is None:
        return None
    return "\n".join(lines[start:end + 1]), start + 1


def check_contract_table(base_mod: Module) -> list[Finding]:
    source = "\n".join(base_mod.lines)
    block = _current_table_block(source)
    expected = generate_contract_table(base_mod)
    if block is None:
        return [_finding(
            "C304", base_mod, 1,
            "base.py has no generated contract table "
            f"({TABLE_START!r} marker missing)",
            "run `python -m repro.analysis --write-contract-table`")]
    current, lineno = block

    def norm(text: str) -> list[str]:
        return [ln.rstrip() for ln in text.splitlines()]

    if norm(current) != norm(expected):
        return [_finding(
            "C304", base_mod, lineno,
            "contract table is out of date with the declared hooks/"
            "primitives",
            "run `python -m repro.analysis --write-contract-table`")]
    return []


def write_contract_table(base_path: Path) -> bool:
    """Rewrite the table block in ``base.py`` in place.  Returns True if
    the file changed."""
    from .astutil import load_modules
    root = base_path.parent
    [mod] = load_modules(root, [base_path])
    source = base_path.read_text()
    expected = generate_contract_table(mod)
    block = _current_table_block(source)
    if block is None:
        raise SystemExit(
            f"{base_path}: no {TABLE_START!r}/{TABLE_END!r} markers to "
            f"rewrite between")
    current, _ = block
    if current == expected:
        return False
    new_source = source.replace(current, expected, 1)
    base_path.write_text(new_source)
    return True

"""The DES schedule sanitizer (``REPRO_SANITIZE=1``): tsan for simulated
time.

The PR 6 stall-gate pruning optimisations (``_l0_stall`` /
``_wb_stall`` dropping entries that "can never gate again") and the
chain ledger's temporal accounting are only correct under scheduling
preconditions the engine never checked at runtime:

* **S401** — per-tree event times are nondecreasing (the global event
  heap dispatches in simulated-time order, so each tree sees a
  monotonic clock — the exact license for dropping cleared L0 entries).
* **S402** — a chain child never starts before its parent finishes
  (``parent_job.t_finish`` is the intra-chain dependency edge).
* **S403** — a ``(tree, level)`` compaction slot is never doubly
  occupied: two jobs reading the same source level of the same tree
  must not overlap in time (``SlotPool.level_free`` exclusivity).
* **S404** — stall-gate queries per tree are issued at nondecreasing
  times (the gates prune history under that assumption).

When ``REPRO_SANITIZE`` is unset this module costs one ``None`` check
per hook site; when set, violations raise
:class:`ScheduleSanitizerError` at the exact first divergence instead
of surfacing three PRs later as an unexplainable parity diff.

This module deliberately imports nothing from ``repro`` — the engine
imports *it*, and the import-graph rule (L106) keeps that edge acyclic.
"""

from __future__ import annotations

import math
import os

ENV_VAR = "REPRO_SANITIZE"

#: slack for float-time comparisons, matching the engine's paranoid checks
EPS = 1e-9


class ScheduleSanitizerError(AssertionError):
    """A DES scheduling invariant was violated (rule S401..S404)."""


class ScheduleSanitizer:
    """Runtime schedule checker, wired into the event heap and the slot
    pools by :class:`repro.core.sim.Simulator` (and the fleet engine,
    which calls :meth:`reset` per temporal pass).

    Hooks:

    * :meth:`on_event` — after each event-heap pop, with the event's
      tree index and simulated time.
    * :meth:`on_gate` — at each ``_l0_stall`` / ``_wb_stall`` query.
    * :meth:`on_schedule` — after a slot pool assigns ``t_start`` /
      ``t_finish`` to a job.
    """

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        """Forget all history (the fleet engine replays many temporal
        passes over one structural phase; each pass is its own
        timeline)."""
        self._event_t: dict[int, float] = {}
        self._gate_t: dict[int, float] = {}
        self._slot_busy_until: dict[tuple[str, int, int], float] = {}
        self.events_checked = 0
        self.jobs_checked = 0

    # ------------------------------------------------------------ hooks
    def on_event(self, tree: int, t: float) -> None:
        """S401: per-tree event times must be nondecreasing."""
        self.events_checked += 1
        last = self._event_t.get(tree, -math.inf)
        if t < last - EPS:
            raise ScheduleSanitizerError(
                f"S401: event time went backwards for tree {tree}: "
                f"{t!r} after {last!r} — the stall-gate pruning "
                f"assumes a monotonic per-tree clock")
        if t > last:
            self._event_t[tree] = t

    def on_gate(self, tree: int, t: float) -> None:
        """S404: stall-gate queries per tree at nondecreasing times."""
        last = self._gate_t.get(tree, -math.inf)
        if t < last - EPS:
            raise ScheduleSanitizerError(
                f"S404: stall gate for tree {tree} queried at {t!r} "
                f"after {last!r} — pruned history would be consulted "
                f"out of order")
        if t > last:
            self._gate_t[tree] = t

    def on_schedule(self, region: int, job) -> None:
        """S402 + S403 for one freshly scheduled job."""
        self.jobs_checked += 1
        parent = getattr(job, "parent_job", None)
        if parent is not None:
            if not parent.scheduled:
                raise ScheduleSanitizerError(
                    f"S402: chain child (chain {job.chain_id}, level "
                    f"{job.level}) scheduled before its parent was "
                    f"scheduled at all")
            if job.t_start < parent.t_finish - EPS:
                raise ScheduleSanitizerError(
                    f"S402: chain child starts at {job.t_start!r} "
                    f"before its parent finishes at "
                    f"{parent.t_finish!r} (chain {job.chain_id})")
        key = (job.kind, region, job.level)
        busy_until = self._slot_busy_until.get(key, -math.inf)
        if job.t_start < busy_until - EPS:
            raise ScheduleSanitizerError(
                f"S403: overlapping occupancy of {job.kind} slot "
                f"(tree {region}, level {job.level}): job starts at "
                f"{job.t_start!r} while the slot is busy until "
                f"{busy_until!r}")
        if job.t_finish > busy_until:
            self._slot_busy_until[key] = job.t_finish


def maybe_sanitizer() -> ScheduleSanitizer | None:
    """A fresh sanitizer when ``REPRO_SANITIZE`` is set (to anything but
    ``0``/empty), else ``None`` — the engine's hook sites cost a single
    ``is not None`` test in the common case."""
    if os.environ.get(ENV_VAR, "0") in ("", "0"):
        return None
    return ScheduleSanitizer()

"""repro-lint: static architecture/determinism analysis for the repro tree.

The repo's correctness story rests on invariants that code review alone
cannot hold: bit-identical fleet-vs-serial parity, byte-identical read
replays, and the mechanism/policy split.  This package checks them
mechanically, over the repo's own AST and import graph:

* :mod:`repro.analysis.layering` — the mechanism (``core/lsm.py`` /
  ``sim.py`` / ``fleet.py``) must not import or branch on concrete
  policies; policies may only touch the tree through the public
  primitives named in ``base.py``'s contract table; ``kernels/`` never
  imports ``core``; the import graph stays acyclic.
* :mod:`repro.analysis.determinism` — wall-clock reads, global RNG,
  set-iteration order, identity-keyed sorts and float reductions over
  unordered containers: the hazards the parity gates depend on.
* :mod:`repro.analysis.contracts` — registered policies implement the
  hook set with compatible signatures, and the generated contract table
  in ``base.py`` matches the actual hooks.
* :mod:`repro.analysis.units` — a dimension-lattice dataflow pass over
  the unit-suffix naming convention (``_s``/``_ms``/``_bytes``/...)
  plus an explicit registry for the unsuffixed hot-path names: flags
  mixed-unit arithmetic, suffix-contradicting stores, double
  conversions, and unsuffixed dimensioned bench-row keys.
* :mod:`repro.analysis.schemas` — statically extracts every bench-row
  dict the emitters produce into per-family schemas and diffs them
  against the generated table in ``docs/benchmarks.md``, the checked-in
  ``BENCH_dbbench.json``, and each other; the same schemas gate row
  emission at runtime under ``REPRO_PARANOID_CHECKS=1``.
* :mod:`repro.analysis.sanitizer` — the runtime half (``REPRO_SANITIZE=1``):
  a DES schedule sanitizer asserting the scheduling-order preconditions
  the stall-gate pruning optimisations assume.

CLI: ``python -m repro.analysis [--format json|github] [--explain RULE]
[paths...]`` — exits non-zero on any finding not covered by the
checked-in baseline (``.repro-lint-baseline.json``).  See
``docs/analysis.md``.
"""

from .catalog import CATALOG, RUNTIME_RULES, STATIC_RULES, explain
from .engine import (DEFAULT_BASELINE_NAME, FAMILIES, analyze_paths,
                     analyze_repo, find_repo_root)
from .findings import Finding, load_baseline, write_baseline
from .sanitizer import ScheduleSanitizer, ScheduleSanitizerError, \
    maybe_sanitizer
from .schemas import (load_schemas, paranoid_validate_rows,
                      validate_emitted_row)

__all__ = [
    "CATALOG",
    "DEFAULT_BASELINE_NAME",
    "FAMILIES",
    "Finding",
    "RUNTIME_RULES",
    "STATIC_RULES",
    "ScheduleSanitizer",
    "ScheduleSanitizerError",
    "analyze_paths",
    "analyze_repo",
    "explain",
    "find_repo_root",
    "load_baseline",
    "load_schemas",
    "maybe_sanitizer",
    "paranoid_validate_rows",
    "validate_emitted_row",
    "write_baseline",
]

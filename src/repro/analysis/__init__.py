"""repro-lint: static architecture/determinism analysis for the repro tree.

The repo's correctness story rests on invariants that code review alone
cannot hold: bit-identical fleet-vs-serial parity, byte-identical read
replays, and the mechanism/policy split.  This package checks them
mechanically, over the repo's own AST and import graph:

* :mod:`repro.analysis.layering` — the mechanism (``core/lsm.py`` /
  ``sim.py`` / ``fleet.py``) must not import or branch on concrete
  policies; policies may only touch the tree through the public
  primitives named in ``base.py``'s contract table; ``kernels/`` never
  imports ``core``; the import graph stays acyclic.
* :mod:`repro.analysis.determinism` — wall-clock reads, global RNG,
  set-iteration order, identity-keyed sorts and float reductions over
  unordered containers: the hazards the parity gates depend on.
* :mod:`repro.analysis.contracts` — registered policies implement the
  hook set with compatible signatures, and the generated contract table
  in ``base.py`` matches the actual hooks.
* :mod:`repro.analysis.sanitizer` — the runtime half (``REPRO_SANITIZE=1``):
  a DES schedule sanitizer asserting the scheduling-order preconditions
  the stall-gate pruning optimisations assume.

CLI: ``python -m repro.analysis [--format json] [paths...]`` — exits
non-zero on any finding not covered by the checked-in baseline
(``.repro-lint-baseline.json``).  See ``docs/analysis.md``.
"""

from .engine import (DEFAULT_BASELINE_NAME, FAMILIES, analyze_paths,
                     analyze_repo, find_repo_root)
from .findings import Finding, load_baseline, write_baseline
from .sanitizer import ScheduleSanitizer, ScheduleSanitizerError, \
    maybe_sanitizer

__all__ = [
    "DEFAULT_BASELINE_NAME",
    "FAMILIES",
    "Finding",
    "ScheduleSanitizer",
    "ScheduleSanitizerError",
    "analyze_paths",
    "analyze_repo",
    "find_repo_root",
    "load_baseline",
    "maybe_sanitizer",
    "write_baseline",
]

"""Findings and the baseline file.

A :class:`Finding` is one rule violation at one source location; its
``fingerprint`` is stable under unrelated line churn (rule id + path +
a hash of the offending line's text), which is what makes a checked-in
baseline practical: old debt stays suppressed while the gate is strict
on new code.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from pathlib import Path

BASELINE_VERSION = 1


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it."""

    rule: str          # e.g. "D201"
    family: str        # "layering" | "determinism" | "contracts"
    path: str          # posix path relative to the analysis root
    line: int          # 1-based
    message: str       # what is wrong
    hint: str          # how to fix it
    snippet: str = ""  # the offending source line (fingerprint input)

    def fingerprint(self) -> str:
        digest = hashlib.sha1(self.snippet.strip().encode()).hexdigest()
        return f"{self.rule}:{self.path}:{digest[:12]}"

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} {self.message}"
                f"\n    hint: {self.hint}")

    def to_dict(self) -> dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d


@dataclass
class Baseline:
    """The set of known, accepted findings (see ``docs/analysis.md``)."""

    fingerprints: set[str] = field(default_factory=set)

    def covers(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.fingerprints


def load_baseline(path: Path | str) -> Baseline:
    path = Path(path)
    if not path.exists():
        return Baseline()
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{data.get('version')!r}")
    return Baseline({e["fingerprint"] for e in data.get("findings", [])})


def write_baseline(path: Path | str, findings: list[Finding]) -> None:
    entries = sorted(
        ({"fingerprint": f.fingerprint(), "rule": f.rule, "path": f.path,
          "note": f.message} for f in findings),
        key=lambda e: (e["path"], e["fingerprint"]))
    payload = {
        "version": BASELINE_VERSION,
        "comment": "Accepted repro-lint findings; regenerate with "
                   "`python -m repro.analysis --write-baseline`. "
                   "Keep empty unless a finding is justified in "
                   "docs/analysis.md.",
        "findings": entries,
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")

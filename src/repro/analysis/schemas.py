"""Bench-schema rules (B6xx): one source of truth for bench-row shapes.

Every BENCH row exists in three places that were hand-synchronized
until now: the emitter dict literal (``bench_kv/db_bench.py``,
``benchmarks/common.py``), the schema tables in ``docs/benchmarks.md``,
and the checked-in ``BENCH_dbbench.json``.  This module *extracts* the
schema from the emitters (per bench family: ordered key set, per-key
unit via ``units.py`` inference + name suffixes) and diffs it three
ways:

* **B601** — the generated schema table in ``docs/benchmarks.md``
  (between the ``bench-schema-start``/``end`` markers) is stale or
  missing; regenerate with
  ``python -m repro.analysis --write-schema-table``.
* **B602** — the checked-in ``BENCH_dbbench.json`` disagrees with the
  emitters: rows with missing/extra keys, families no emitter
  produces, emitters no row exercises, or non-numeric values under a
  dimensioned key.
* **B603** — the same key name carries two different units in two
  families (``stall_s`` seconds here, milliseconds there).

Extraction understands the emitter idioms in this repo: dict literals
with a ``"bench"`` key; ``row["k"] = ...`` augmentation;
``row.update({...})`` (optional keys) and ``row.update(call())``
(*open* schema — dynamic payload, extra keys allowed);
parameterized families (``_sweep_row``'s ``bench`` parameter, one
concrete variant per distinct call-site value, caller-side key adds
attached to the right variant); and ``ROWS.append({...})`` in
``benchmarks/common.py`` (the ``run_csv`` family).

Unlike the path-scoped families, this one is **root-scoped**: it
always loads the fixed emitter/doc/JSON inputs below the analysis
root (skipping whichever are absent, so fixture trees work), no matter
which paths were selected.  The same extraction backs the runtime
check: ``validate_row()`` is called from the emitters when
``REPRO_PARANOID_CHECKS`` is on.
"""

from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field
from pathlib import Path

from . import units
from .astutil import Module, load_modules
from .findings import Finding

FAMILY = "schemas"

#: the emitter files, relative to the analysis root (missing ones skip)
EMITTER_RELS = ("src/repro/bench_kv/db_bench.py", "benchmarks/common.py")
DOC_REL = "docs/benchmarks.md"
JSON_REL = "BENCH_dbbench.json"
TABLE_START = "<!-- bench-schema-start -->"
TABLE_END = "<!-- bench-schema-end -->"
#: family name for benchmarks/common.py's ``ROWS.append({...})`` rows
CSV_FAMILY = "run_csv"


@dataclass
class Variant:
    """One emitted row shape: a bench family as one dict literal sees it."""

    family: str
    path: str                                # emitter module, root-relative
    line: int                                # the dict literal
    keys: dict[str, str | None]              # required, in literal order
    optional: dict[str, str | None] = field(default_factory=dict)
    open: bool = False                       # dynamic update(): subset match

    def unit_of(self, key: str) -> str | None:
        return self.keys.get(key) or self.optional.get(key)

    def all_keys(self) -> dict[str, str | None]:
        merged = dict(self.keys)
        for k, u in self.optional.items():
            merged.setdefault(k, u)
        return merged

    def matches(self, row_keys: set[str]) -> bool:
        if not row_keys >= set(self.keys):
            return False
        return self.open or row_keys <= set(self.keys) | set(self.optional)


def _finding(rule: str, path: str, line: int, message: str, hint: str,
             snippet: str = "") -> Finding:
    return Finding(rule=rule, family=FAMILY, path=path, line=line,
                   message=message, hint=hint, snippet=snippet)


# --------------------------------------------------------------------------
# extraction

def _str_keys(node: ast.Dict) -> list[str | None]:
    return [k.value if isinstance(k, ast.Constant)
            and isinstance(k.value, str) else None for k in node.keys]


def _enclosing_functions(tree: ast.Module) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _param_default(fn: ast.FunctionDef, name: str) -> str | None:
    """String default of parameter ``name``, if any."""
    a = fn.args
    pos = list(a.posonlyargs) + list(a.args)
    for arg, dflt in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if arg.arg == name and isinstance(dflt, ast.Constant) \
                and isinstance(dflt.value, str):
            return dflt.value
    for arg, dflt in zip(a.kwonlyargs, a.kw_defaults):
        if arg.arg == name and isinstance(dflt, ast.Constant) \
                and isinstance(dflt.value, str):
            return dflt.value
    return None


def _key_units(mod: Module, fn: ast.FunctionDef | None,
               node: ast.Dict) -> dict[str, str | None]:
    """Effective unit per key: the name-declared unit (suffix/registry)
    first, the value-inferred unit as fallback."""
    inferred = units.dict_key_units(mod, fn, node)
    out: dict[str, str | None] = {}
    for key in _str_keys(node):
        if key is None:
            continue
        out[key] = units.name_unit(key) or inferred.get(key)
    return out


@dataclass
class _Template:
    """A dict literal whose ``"bench"`` value is a function parameter."""

    fn_name: str
    param: str
    default: str | None
    skeleton: Variant
    #: family → caller-side additions {key: (unit, conditional)}
    call_adds: dict[str, dict[str, tuple[str | None, bool]]] \
        = field(default_factory=dict)
    families: set[str] = field(default_factory=set)


class _ModuleExtractor:
    """Per-module pass: concrete variants, templates, template calls."""

    def __init__(self, mod: Module):
        self.mod = mod
        self.variants: list[Variant] = []
        self.templates: dict[str, _Template] = {}   # by fn name
        #: (callee, family-or-None, additions {key: (unit, cond)})
        self.calls: list[tuple[str, str | None,
                               dict[str, tuple[str | None, bool]]]] = []

    def run(self) -> None:
        self._scan_fn(None, self.mod.tree.body)
        for fn in _enclosing_functions(self.mod.tree):
            self._scan_fn(fn, fn.body)

    # -- one scope ---------------------------------------------------------
    def _scan_fn(self, fn: ast.FunctionDef | None,
                 body: list[ast.stmt]) -> None:
        # all bench-dicts in this scope (excluding nested defs)
        dicts = self._bench_dicts(body)
        bound: dict[str, Variant] = {}       # var name → its variant
        #: var → (callee, bench-kwarg, caller-side key additions) for
        #: ``row = _sweep_row(...); row["engine"] = ...`` idioms
        pending: dict[str, tuple[str, str | None,
                                 dict[str, tuple[str | None, bool]]]] = {}
        made: dict[int, Variant] = {}
        #: bench-less dicts bound to a name become variants only if the
        #: name later reaches ``ROWS.append`` (``row = {...}`` idiom)
        provisional: set[int] = set()

        for node in dicts:
            v = self._variant_of(fn, node)
            if v is not None:
                made[id(node)] = v
                self.variants.append(v)

        def visit(stmts: list[ast.stmt], cond: bool) -> None:
            for st in stmts:
                if isinstance(st, ast.Assign) and len(st.targets) == 1:
                    tgt, val = st.targets[0], st.value
                    if isinstance(tgt, ast.Name):
                        if id(val) in made:
                            bound[tgt.id] = made[id(val)]
                        elif isinstance(val, ast.Dict) \
                                and "bench" not in _str_keys(val):
                            v = Variant(family=CSV_FAMILY,
                                        path=self.mod.rel, line=val.lineno,
                                        keys=_key_units(self.mod, fn, val))
                            bound[tgt.id] = v
                            provisional.add(id(v))
                        elif isinstance(val, ast.Call):
                            callee = self._callee(val)
                            if callee:
                                pending[tgt.id] = (
                                    callee, self._call_family(val), {})
                    elif isinstance(tgt, ast.Subscript) \
                            and isinstance(tgt.value, ast.Name):
                        self._add_key(fn, bound, pending, tgt.value.id,
                                      tgt.slice, st.value, cond)
                elif isinstance(st, ast.Expr) \
                        and isinstance(st.value, ast.Call):
                    call = st.value
                    if isinstance(call.func, ast.Attribute) \
                            and call.func.attr == "append" \
                            and isinstance(call.func.value, ast.Name) \
                            and call.func.value.id == "ROWS" \
                            and call.args \
                            and isinstance(call.args[0], ast.Name) \
                            and call.args[0].id in bound:
                        v = bound[call.args[0].id]
                        if id(v) in provisional:
                            provisional.discard(id(v))
                            self.variants.append(v)
                    self._update_stmt(fn, bound, call)
                for sub, subcond in self._sub_bodies(st):
                    visit(sub, cond or subcond)

        visit(body, False)
        self.calls.extend(pending.values())

    def _sub_bodies(self, st: ast.stmt):
        if isinstance(st, (ast.If, ast.For, ast.While)):
            yield st.body, True
            yield st.orelse, True
        elif isinstance(st, ast.With):
            yield st.body, False
        elif isinstance(st, ast.Try):
            yield st.body, False
            for h in st.handlers:
                yield h.body, True
            yield st.orelse, True
            yield st.finalbody, True

    def _bench_dicts(self, body: list[ast.stmt]) -> list[ast.Dict]:
        found: list[ast.Dict] = []

        def walk(node: ast.AST) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.ClassDef)):
                    continue
                if isinstance(child, ast.Dict) \
                        and "bench" in _str_keys(child):
                    found.append(child)
                if isinstance(child, ast.Call) \
                        and isinstance(child.func, ast.Attribute) \
                        and child.func.attr == "append" \
                        and isinstance(child.func.value, ast.Name) \
                        and child.func.value.id == "ROWS" \
                        and child.args \
                        and isinstance(child.args[0], ast.Dict):
                    found.append(child.args[0])
                walk(child)

        for st in body:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue           # separate scopes: run() visits defs
            walk(st)
        # a ROWS.append dict may also carry a "bench" key; dedupe
        seen: set[int] = set()
        uniq = []
        for d in found:
            if id(d) not in seen:
                seen.add(id(d))
                uniq.append(d)
        return uniq

    def _variant_of(self, fn: ast.FunctionDef | None,
                    node: ast.Dict) -> Variant | None:
        keys = _str_keys(node)
        key_units = _key_units(self.mod, fn, node)
        if "bench" not in keys:                  # ROWS.append literal
            return Variant(family=CSV_FAMILY, path=self.mod.rel,
                           line=node.lineno, keys=key_units)
        bench_val = node.values[keys.index("bench")]
        if isinstance(bench_val, ast.Constant) \
                and isinstance(bench_val.value, str):
            return Variant(family=bench_val.value, path=self.mod.rel,
                           line=node.lineno, keys=key_units)
        if isinstance(bench_val, ast.Name) and fn is not None:
            default = _param_default(fn, bench_val.id)
            if default is not None:
                skel = Variant(family=default, path=self.mod.rel,
                               line=node.lineno, keys=key_units)
                self.templates[fn.name] = _Template(
                    fn_name=fn.name, param=bench_val.id,
                    default=default, skeleton=skel)
                return None                      # realized per call site
        # dynamic family we can't resolve: skip rather than guess
        return None

    def _callee(self, call: ast.Call) -> str | None:
        if isinstance(call.func, ast.Name):
            return call.func.id
        if isinstance(call.func, ast.Attribute):
            return call.func.attr
        return None

    def _call_family(self, call: ast.Call) -> str | None:
        for kw in call.keywords:
            if kw.arg == "bench" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                return kw.value.value
        return None

    def _add_key(self, fn, bound, pending, var: str, slice_node: ast.AST,
                 value: ast.AST, cond: bool) -> None:
        if not (isinstance(slice_node, ast.Constant)
                and isinstance(slice_node.value, str)):
            return
        key = slice_node.value
        unit = units.name_unit(key)
        if var in bound:
            v = bound[var]
            (v.optional if cond else v.keys).setdefault(key, unit)
        elif var in pending:
            pending[var][2].setdefault(key, (unit, cond))

    def _update_stmt(self, fn, bound, call: ast.Call) -> None:
        """``var.update({...})`` → optional keys; dynamic arg → open."""
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr == "update"
                and isinstance(call.func.value, ast.Name)
                and call.func.value.id in bound and call.args):
            return
        v = bound[call.func.value.id]
        arg = call.args[0]
        if isinstance(arg, ast.Dict):
            for key, unit in _key_units(self.mod, fn, arg).items():
                if key not in v.keys:
                    v.optional.setdefault(key, unit)
        else:
            v.open = True


def extract_variants(root: Path) -> list[Variant]:
    """All emitted row shapes below ``root`` (families realized from
    templates and call sites across the emitter modules)."""
    root = Path(root).resolve()
    paths = [root / rel for rel in EMITTER_RELS if (root / rel).exists()]
    if not paths:
        return []
    mods = load_modules(root, paths)
    extractors = [_ModuleExtractor(m) for m in mods]
    templates: dict[str, _Template] = {}
    for ex in extractors:
        ex.run()
        templates.update(ex.templates)

    # every template call anywhere in the emitter set realizes a family
    calls: list[tuple[str, str | None,
                      dict[str, tuple[str | None, bool]]]] = []
    for ex in extractors:
        calls.extend(ex.calls)
        for mod_call in ast.walk(ex.mod.tree):
            if isinstance(mod_call, ast.Call):
                callee = ex._callee(mod_call)
                if callee in templates:
                    calls.append((callee,
                                  ex._call_family(mod_call), {}))

    variants = [v for ex in extractors for v in ex.variants]
    for tpl in templates.values():
        fams: dict[str, dict[str, tuple[str | None, bool]]] = {}
        for callee, fam, adds in calls:
            if callee != tpl.fn_name:
                continue
            family = fam or tpl.default
            if family is None:
                continue
            merged = fams.setdefault(family, {})
            for k, (u, cond) in adds.items():
                merged.setdefault(k, (u, cond))
        if not fams and tpl.default:
            fams[tpl.default] = {}
        for family, adds in fams.items():
            v = Variant(family=family, path=tpl.skeleton.path,
                        line=tpl.skeleton.line,
                        keys=dict(tpl.skeleton.keys),
                        optional=dict(tpl.skeleton.optional),
                        open=tpl.skeleton.open)
            for k, (u, _cond) in adds.items():
                v.optional.setdefault(k, u)
            variants.append(v)
    variants.sort(key=lambda v: (v.family, v.path, v.line))
    return variants


# --------------------------------------------------------------------------
# B601: the generated schema table in docs/benchmarks.md

def _render_keys(v: Variant) -> str:
    parts = []
    for k, u in v.keys.items():
        parts.append(f"`{k}`:{u or '?'}")
    for k, u in v.optional.items():
        if k not in v.keys:
            parts.append(f"+`{k}`:{u or '?'}")
    if v.open:
        parts.append("…")
    return ", ".join(parts)


def generate_schema_table(variants: list[Variant]) -> str:
    """Deterministic markdown for the doc block; both the B601 check and
    ``--write-schema-table`` call this, so they cannot drift."""
    lines = [
        TABLE_START,
        "",
        "*Generated by `python -m repro.analysis --write-schema-table` — "
        "do not edit by hand (B601 fails CI on drift).  Units: s, ms, "
        "bytes, MB, ops, ops/s, bytes/s, 1 (dimensionless), ? (untyped); "
        "`+key` is optional, `…` marks an open schema (dynamic "
        "`update()` payload).*",
        "",
        "| bench family | emitter | emitted keys |",
        "|---|---|---|",
    ]
    for v in variants:
        lines.append(f"| `{v.family}` | `{v.path}:{v.line}` "
                     f"| {_render_keys(v)} |")
    lines += ["", TABLE_END]
    return "\n".join(lines)


def _current_doc_block(text: str) -> tuple[str, int] | None:
    lines = text.splitlines()
    start = end = None
    for i, ln in enumerate(lines):
        if TABLE_START in ln and start is None:
            start = i
        elif TABLE_END in ln and start is not None:
            end = i
            break
    if start is None or end is None:
        return None
    return "\n".join(lines[start:end + 1]), start + 1


def check_schema_table(root: Path, variants: list[Variant]
                       ) -> list[Finding]:
    doc = Path(root) / DOC_REL
    if not doc.exists():
        return []
    text = doc.read_text()
    block = _current_doc_block(text)
    hint = "run `python -m repro.analysis --write-schema-table`"
    if block is None:
        return [_finding("B601", DOC_REL, 1,
                         f"{DOC_REL} has no generated schema table "
                         f"({TABLE_START!r} marker missing)", hint)]
    current, lineno = block

    def norm(t: str) -> list[str]:
        return [ln.rstrip() for ln in t.splitlines()]

    if norm(current) != norm(generate_schema_table(variants)):
        return [_finding(
            "B601", DOC_REL, lineno,
            "schema table is out of date with the emitter dict literals",
            hint, snippet=TABLE_START)]
    return []


def write_schema_table(root: Path) -> bool:
    """Rewrite the doc block in place; True if the file changed."""
    root = Path(root).resolve()
    doc = root / DOC_REL
    variants = extract_variants(root)
    text = doc.read_text()
    block = _current_doc_block(text)
    expected = generate_schema_table(variants)
    if block is None:
        raise SystemExit(f"{doc}: no {TABLE_START!r}/{TABLE_END!r} "
                         f"markers to rewrite between")
    current, _ = block
    if current == expected:
        return False
    doc.write_text(text.replace(current, expected, 1))
    return True


# --------------------------------------------------------------------------
# B602: the checked-in JSON vs the emitters

_DIMENSIONED = set(units.UNITS) - {units.DIMENSIONLESS}


def _closest(variants: list[Variant], row_keys: set[str]) -> Variant:
    return min(variants,
               key=lambda v: len(row_keys ^ set(v.all_keys())))


def check_json(root: Path, variants: list[Variant]) -> list[Finding]:
    path = Path(root) / JSON_REL
    if not path.exists():
        return []
    try:
        rows = json.loads(path.read_text())
    except ValueError as e:
        return [_finding("B602", JSON_REL, 1,
                         f"{JSON_REL} is not valid JSON: {e}",
                         "regenerate with `python -m repro.bench_kv."
                         "db_bench --json BENCH_dbbench.json`")]
    if not isinstance(rows, list):
        return []
    by_family: dict[str, list[Variant]] = {}
    for v in variants:
        by_family.setdefault(v.family, []).append(v)

    findings: list[Finding] = []
    seen_shape: set[tuple] = set()
    seen_type: set[tuple] = set()
    row_families: set[str] = set()
    hint = ("regenerate BENCH_dbbench.json (`python -m repro.bench_kv."
            "db_bench --json BENCH_dbbench.json`) or fix the emitter")
    for row in rows:
        if not isinstance(row, dict) or "bench" not in row:
            continue
        family = row["bench"]
        row_families.add(family)
        fam_variants = by_family.get(family)
        if not fam_variants:
            if ("nofam", family) not in seen_shape:
                seen_shape.add(("nofam", family))
                findings.append(_finding(
                    "B602", JSON_REL, 1,
                    f'{JSON_REL} has rows for bench family "{family}" '
                    f"that no emitter produces", hint))
            continue
        errors = validate_row(row, fam_variants)
        if errors:
            best = _closest(fam_variants, set(row))
            for err in errors:
                kind = (family, err)
                if kind in seen_shape:
                    continue
                seen_shape.add(kind)
                findings.append(_finding(
                    "B602", best.path, best.line,
                    f'family "{family}" rows in {JSON_REL}: {err}',
                    hint, snippet=f"{family}:{err}"))
        else:
            v = next(v for v in fam_variants if v.matches(set(row)))
            for key, unit in v.all_keys().items():
                if unit in _DIMENSIONED and key in row \
                        and not isinstance(row[key], (int, float)):
                    kind = (family, key, "type")
                    if kind in seen_type:
                        continue
                    seen_type.add(kind)
                    findings.append(_finding(
                        "B602", v.path, v.line,
                        f'family "{family}" key "{key}" is {unit} but '
                        f"{JSON_REL} holds "
                        f"{type(row[key]).__name__} values",
                        hint, snippet=f"{family}:{key}:type"))
    # db_bench emitters never exercised by the checked-in rows
    if row_families:
        for v in variants:
            if v.path.endswith("db_bench.py") \
                    and v.family not in row_families:
                findings.append(_finding(
                    "B602", v.path, v.line,
                    f'emitter family "{v.family}" has no rows in '
                    f"{JSON_REL}", hint,
                    snippet=f"{v.family}:norows"))
    return findings


def validate_row(row: dict, variants: list[Variant]) -> list[str]:
    """Shape errors of one row against a family's variants (empty =
    valid).  The runtime paranoid check in the emitters calls this."""
    row_keys = set(row)
    if any(v.matches(row_keys) for v in variants):
        return []
    best = _closest(variants, row_keys)
    missing = sorted(set(best.keys) - row_keys)
    extra = sorted(row_keys - set(best.all_keys()))
    errors = []
    if missing:
        errors.append(f"missing key(s) {missing} "
                      f"(vs {best.path}:{best.line})")
    if extra and not best.open:
        errors.append(f"extra key(s) {extra} "
                      f"(vs {best.path}:{best.line})")
    if not errors:
        errors.append(f"does not match any emitter variant "
                      f"(closest: {best.path}:{best.line})")
    return errors


# --------------------------------------------------------------------------
# B603: cross-family unit consistency

def check_cross_family(variants: list[Variant]) -> list[Finding]:
    seen: dict[str, tuple[str, Variant]] = {}   # key → (unit, first site)
    findings: list[Finding] = []
    for v in variants:
        for key, unit in v.all_keys().items():
            if unit is None:
                continue
            prev = seen.get(key)
            if prev is None:
                seen[key] = (unit, v)
            elif prev[0] != unit:
                pu, pv = prev
                findings.append(_finding(
                    "B603", v.path, v.line,
                    f'key "{key}" is {unit} in family "{v.family}" but '
                    f'{pu} in family "{pv.family}" '
                    f"({pv.path}:{pv.line})",
                    "one key name, one unit: rename one side or convert",
                    snippet=f"{key}:{v.family}"))
    return findings


# --------------------------------------------------------------------------
# entry points

def check(root: Path) -> list[Finding]:
    root = Path(root).resolve()
    variants = extract_variants(root)
    if not variants:
        return []
    findings = (check_schema_table(root, variants)
                + check_json(root, variants)
                + check_cross_family(variants))
    # apply inline suppressions against the emitter sources
    mods = {m.rel: m for m in load_modules(
        root, [root / rel for rel in EMITTER_RELS
               if (root / rel).exists()])}
    out = []
    for f in findings:
        mod = mods.get(f.path)
        if mod is not None and mod.suppressed(f.line, f.rule):
            continue
        out.append(f)
    return out


# -- runtime validation (REPRO_PARANOID_CHECKS) ----------------------------
_SCHEMA_CACHE: dict[str, dict[str, list[Variant]]] = {}


def load_schemas(root: Path | None = None) -> dict[str, list[Variant]]:
    """family → variants, extracted once per root and cached (the
    emitters call this on every row when paranoid checks are on)."""
    from .engine import find_repo_root
    root = Path(root) if root else find_repo_root()
    key = str(root)
    if key not in _SCHEMA_CACHE:
        by_family: dict[str, list[Variant]] = {}
        for v in extract_variants(root):
            by_family.setdefault(v.family, []).append(v)
        _SCHEMA_CACHE[key] = by_family
    return _SCHEMA_CACHE[key]


def paranoid_validate_rows(rows: list[dict], family: str | None = None,
                           root: Path | None = None) -> None:
    """Validate every row against its extracted schema when
    ``REPRO_PARANOID_CHECKS=1`` — a drifting emitter then fails the
    smoke run itself, not just the linter.  No-op otherwise."""
    import os
    if os.environ.get("REPRO_PARANOID_CHECKS", "0") != "1":
        return
    for row in rows:
        if isinstance(row, dict):
            validate_emitted_row(row, family=family, root=root)


def validate_emitted_row(row: dict, family: str | None = None,
                         root: Path | None = None) -> None:
    """Raise ``ValueError`` when ``row`` does not match its family's
    extracted schema.  No-op when the family is unknown to the
    extractor (so ad-hoc rows stay possible)."""
    schemas = load_schemas(root)
    fam = family if family is not None else row.get("bench")
    variants = schemas.get(fam)
    if not variants:
        return
    errors = validate_row(row, variants)
    if errors:
        raise ValueError(
            f"bench row for family {fam!r} drifted from the emitter "
            f"schema: {'; '.join(errors)} — rerun `python -m "
            f"repro.analysis --rules schemas`")

"""Unit-dimension rules (U5xx): a dataflow pass over physical quantities.

The paper's headline numbers are dimensioned — P99 latency (s vs ms),
I/O amplification (dimensionless), offered load (ops/s) — and the repo
moves them across four layers guarded only by naming conventions
(``p99_ms``, ``stall_total_s``, ``*_bytes``).  This pass makes the
convention load-bearing: it infers a unit for every expression from

* **name suffixes** — ``_s``, ``_ms``, ``_bytes``, ``_mb``, ``_ops``,
  ``_ops_s``/``_ops_per_s``, ``_bytes_per_s``, ``_amp``/``_frac``/
  ``_ratio``/``_pct`` (dimensionless);
* **the registry** — a small explicit table for unsuffixed hot-path
  names (``latency``, ``arrivals``, ``service`` are seconds arrays in
  ``sim.py``/``fleet.py``; ``busy`` is a dimensionless count;
  ``throughput`` is ops/s; ``pct()``/``perf_counter()`` return
  seconds) — the registry contract is documented in
  ``docs/analysis.md``;
* **function signatures** — a function named with a unit suffix returns
  that unit (``sst_bytes(...)`` → bytes), parameters carry their
  name-derived units into the body;

and walks each function body sequentially (alias tracking in the style
of ``determinism.py``), propagating units through arithmetic,
``round``/``float``/numpy passthroughs, subscripts and attributes.
Inference is *conservative*: anything not provably dimensioned is
UNKNOWN and combines freely — the rules only fire when both sides are
known and contradictory.

* **U501** — mixed-unit ``+``/``-``/comparison (seconds vs ms, ...).
* **U502** — an assignment / return / dict entry whose target name ends
  in a unit suffix receives a value of a *different* known unit without
  a recognized conversion factor (``* 1e3``, ``/ 1e6``,
  ``round(x * 1e3, 3)``).
* **U503** — a conversion factor applied to an already-converted value
  (``ms * 1e3``, ``mb / 1e6``): double conversion.
* **U504** — an unsuffixed key in a bench-row dict (a dict literal with
  a ``"bench"`` key) carries a value with a known dimension — the key
  name must state the unit the JSON row readers will assume.

``# lint-ok`` suppression and the churn-stable fingerprint/baseline
machinery apply as for every other family.
"""

from __future__ import annotations

import ast

from .astutil import Module, dotted
from .findings import Finding

FAMILY = "units"

# -- the dimension lattice -------------------------------------------------
SECONDS = "s"
MILLISECONDS = "ms"
BYTES = "bytes"
MEGABYTES = "MB"
OPS = "ops"
OPS_PER_S = "ops/s"
BYTES_PER_S = "bytes/s"
DIMENSIONLESS = "1"
#: every known unit, in display order (docs table + --explain)
UNITS = (SECONDS, MILLISECONDS, BYTES, MEGABYTES, OPS, OPS_PER_S,
         BYTES_PER_S, DIMENSIONLESS)
UNKNOWN = None

# -- name suffixes ---------------------------------------------------------
#: ordered: first match wins (``_ops_s`` must beat ``_s``)
_SUFFIXES: tuple[tuple[tuple[str, ...], str], ...] = (
    (("_ops_per_s", "_ops_s", "ops_per_s"), OPS_PER_S),
    (("_bytes_per_s", "bytes_per_s", "_bps"), BYTES_PER_S),
    (("_ms",), MILLISECONDS),
    (("_s",), SECONDS),
    (("_mb",), MEGABYTES),
    (("_bytes",), BYTES),
    (("_ops",), OPS),
    (("_amp", "_frac", "_ratio", "_pct", "_share"), DIMENSIONLESS),
)

#: whole (or terminal-``_``-segment) names with a fixed unit — the
#: explicit registry for unsuffixed hot-path quantities.  Kept small on
#: purpose; the contract is documented in docs/analysis.md and changes
#: here must be mirrored there (the check_links.py drift check covers
#: the rule ids, the registry rides in the U5xx section).
NAME_REGISTRY: dict[str, str] = {
    "latency": SECONDS,       # SimResult.latency / per-op sojourn arrays
    "arrivals": SECONDS,      # arrival timestamp arrays
    "service": SECONDS,       # per-op service-demand arrays
    "departures": SECONDS,
    "makespan": SECONDS,
    "wall": SECONDS,          # perf_counter deltas in the emitters
    "busy": DIMENSIONLESS,    # busy-server count (sim.py BUSY_ALPHA path)
    "throughput": OPS_PER_S,
    "ops": OPS,               # bench-row op counts ("ops": n_ops)
    # sweep-executor phase timing (core/sweeps.py PointTiming): the
    # ``_s`` suffix already resolves these, but the executor's bench-row
    # contract is pinned here explicitly so renames surface as registry
    # drift, not silent unit loss.
    "structural_s": SECONDS,      # phase A (structural replay) wall
    "temporal_s": SECONDS,        # per-schedule temporal-pass wall
    "lindley_s": SECONDS,         # per-schedule Lindley-scan wall
    "finalize_s": SECONDS,        # per-schedule finalize wall
    "executor_wall_s": SECONDS,   # perf_trajectory: executor wall-clock
    "serial_equiv_s": SECONDS,    # perf_trajectory: summed task compute
}

#: callables whose *return* unit is fixed (matched on the terminal
#: attribute/name of the callee)
CALL_REGISTRY: dict[str, str] = {
    "perf_counter": SECONDS,  # time.perf_counter() — measuring, not logic
    "pct": SECONDS,           # SimResult.pct(q): latency percentile
}

#: callables transparent to units: unit(f(x)) == unit(x); for the
#: variadic ones (min/max/...) the argument units are joined
_PASSTHROUGH_CALLS = {
    "round", "float", "int", "abs", "sorted", "sum", "min", "max",
    "percentile", "quantile", "mean", "median", "cumsum", "asarray",
    "ascontiguousarray", "maximum", "minimum", "accumulate", "where",
    "concatenate", "stack", "hstack", "clip", "nan_to_num", "array",
}
#: zero-argument-ish methods transparent to units (x.astype(...), x.copy())
_PASSTHROUGH_METHODS = {
    "astype", "copy", "mean", "sum", "max", "min", "item", "tolist",
    "ravel", "reshape", "squeeze", "round", "clip", "cumsum", "take",
}

# -- conversion constants --------------------------------------------------
_KILO = "KILO"       # 1e3 / 1000
_MILLI = "MILLI"     # 1e-3
_MEGA = "MEGA"       # 1e6 / 1_000_000 / (1 << 20)
_SCALAR = "SCALAR"   # any other numeric literal

#: unit × constant → unit for ``*``; the string "U503" flags a double
#: conversion instead of producing a unit
_MUL_CONV: dict[tuple[str, str], str] = {
    (SECONDS, _KILO): MILLISECONDS,
    (MILLISECONDS, _MILLI): SECONDS,
    (MILLISECONDS, _KILO): "U503",
    (MEGABYTES, _MEGA): BYTES,
    (BYTES, _MEGA): "U503",
}
#: unit × constant → unit for ``/``
_DIV_CONV: dict[tuple[str, str], str] = {
    (MILLISECONDS, _KILO): SECONDS,
    (BYTES, _MEGA): MEGABYTES,
    (MEGABYTES, _MEGA): "U503",
    (SECONDS, _MILLI): MILLISECONDS,
}
#: unit × unit → unit for ``*`` (symmetric; dimensionless handled apart)
_MUL_UNITS: dict[tuple[str, str], str] = {
    (SECONDS, OPS_PER_S): OPS,
    (SECONDS, BYTES_PER_S): BYTES,
}
#: unit / unit → unit
_DIV_UNITS: dict[tuple[str, str], str] = {
    (OPS, SECONDS): OPS_PER_S,
    (BYTES, SECONDS): BYTES_PER_S,
    (OPS, OPS_PER_S): SECONDS,
    (BYTES, BYTES_PER_S): SECONDS,
}


def suffix_unit(name: str | None) -> str | None:
    """Unit implied by a name's suffix, or None."""
    if not name:
        return UNKNOWN
    low = name.lower()
    for suffixes, unit in _SUFFIXES:
        for suf in suffixes:
            if low.endswith(suf):
                return unit
    return UNKNOWN


def name_unit(name: str | None) -> str | None:
    """Unit of a bare name: suffix first, then the registry (matched on
    the whole name and on its terminal ``_`` segment, so ``run_arrivals``
    and ``res.latency`` both resolve)."""
    if not name:
        return UNKNOWN
    u = suffix_unit(name)
    if u is not UNKNOWN:
        return u
    if name in NAME_REGISTRY:
        return NAME_REGISTRY[name]
    tail = name.rsplit("_", 1)[-1]
    return NAME_REGISTRY.get(tail, UNKNOWN)


def _const_value(node: ast.AST) -> float | None:
    """Numeric value of a literal expression (1e3, 1000, 1 << 20, -1)."""
    try:
        v = ast.literal_eval(node)
    except (ValueError, SyntaxError, TypeError, MemoryError):
        return None
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v)


def _const_class(node: ast.AST) -> str | None:
    v = _const_value(node)
    if v is None:
        return None
    if v in (1e3,):
        return _KILO
    if v in (1e-3,):
        return _MILLI
    if v in (1e6, float(1 << 20)):
        return _MEGA
    return _SCALAR


def _join(units: list[str | None]) -> str | None:
    """Least upper bound of element units: all known-and-equal → that
    unit (unknowns are optimistic and don't poison the join)."""
    known = {u for u in units if u is not UNKNOWN}
    if len(known) == 1:
        return known.pop()
    return UNKNOWN


def _callee_tail(func: ast.AST) -> str | None:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class UnitEvaluator:
    """Per-module unit inference + U5xx flagging.

    One instance per module; ``run()`` walks the module body and every
    function def as an independent sequential scope.  Pass
    ``collect=False`` to reuse the inference without emitting findings
    (``schemas.py`` does, for per-key units of bench-row dicts).
    """

    def __init__(self, mod: Module, collect: bool = True):
        self.mod = mod
        self.collect = collect
        self.findings: list[Finding] = []

    # -- findings ----------------------------------------------------------
    def _flag(self, rule: str, node: ast.AST, message: str,
              hint: str) -> None:
        if not self.collect:
            return
        lineno = getattr(node, "lineno", 1)
        self.findings.append(Finding(
            rule=rule, family=FAMILY, path=self.mod.rel, line=lineno,
            message=message, hint=hint, snippet=self.mod.line(lineno)))

    # -- scopes ------------------------------------------------------------
    def run(self) -> list[Finding]:
        self._walk_body(self.mod.tree.body, env={}, fn_unit=UNKNOWN)
        for fn in self._functions(self.mod.tree):
            self.function_env(fn)
        return self.findings

    def _functions(self, tree: ast.AST) -> list[ast.FunctionDef]:
        fns = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fns.append(node)
        return fns

    def function_env(self, fn: ast.FunctionDef) -> dict[str, str]:
        """Sequentially walk one function body; returns the final
        name → unit environment (used by schemas.py)."""
        env: dict[str, str] = {}
        a = fn.args
        for arg in (list(a.posonlyargs) + list(a.args)
                    + list(a.kwonlyargs)):
            u = name_unit(arg.arg)
            if u is not UNKNOWN:
                env[arg.arg] = u
        ret = name_unit(fn.name)
        self._walk_body(fn.body, env, fn_unit=ret, fn_name=fn.name)
        return env

    # -- statements --------------------------------------------------------
    def _walk_body(self, stmts: list[ast.stmt], env: dict[str, str],
                   fn_unit: str | None, fn_name: str = "") -> None:
        for st in stmts:
            self._statement(st, env, fn_unit, fn_name)

    def _statement(self, st: ast.stmt, env: dict[str, str],
                   fn_unit: str | None, fn_name: str) -> None:
        if isinstance(st, ast.Assign):
            v = self.infer(st.value, env)
            for tgt in st.targets:
                self._bind(tgt, st.value, v, env)
        elif isinstance(st, ast.AnnAssign) and st.value is not None:
            v = self.infer(st.value, env)
            self._bind(st.target, st.value, v, env)
        elif isinstance(st, ast.AugAssign):
            t = self._target_unit(st.target, env)
            v = self.infer(st.value, env)
            if isinstance(st.op, (ast.Add, ast.Sub)) and t and v \
                    and t != v and DIMENSIONLESS not in (t, v):
                self._flag("U501", st,
                           f"augmented {self._opname(st.op)} mixes units: "
                           f"target is {t}, value is {v}",
                           "convert explicitly (* 1e3 for s→ms, / 1e6 "
                           "for bytes→MB) or fix the name")
        elif isinstance(st, ast.Return):
            if st.value is not None:
                v = self.infer(st.value, env)
                if fn_unit and v and fn_unit != v \
                        and DIMENSIONLESS not in (fn_unit, v):
                    self._flag(
                        "U502", st,
                        f"{fn_name}() is named as {fn_unit} but returns "
                        f"{v}",
                        "apply the conversion at the return site or "
                        "rename the function")
        elif isinstance(st, ast.For):
            it = self.infer(st.iter, env)
            if isinstance(st.target, ast.Name) and it is not UNKNOWN:
                env[st.target.id] = it       # element of a typed array
            self._walk_body(st.body, env, fn_unit, fn_name)
            self._walk_body(st.orelse, env, fn_unit, fn_name)
        elif isinstance(st, (ast.While,)):
            self.infer(st.test, env)
            self._walk_body(st.body, env, fn_unit, fn_name)
            self._walk_body(st.orelse, env, fn_unit, fn_name)
        elif isinstance(st, ast.If):
            self.infer(st.test, env)
            self._walk_body(st.body, env, fn_unit, fn_name)
            self._walk_body(st.orelse, env, fn_unit, fn_name)
        elif isinstance(st, ast.With):
            for item in st.items:
                self.infer(item.context_expr, env)
            self._walk_body(st.body, env, fn_unit, fn_name)
        elif isinstance(st, ast.Try):
            self._walk_body(st.body, env, fn_unit, fn_name)
            for h in st.handlers:
                self._walk_body(h.body, env, fn_unit, fn_name)
            self._walk_body(st.orelse, env, fn_unit, fn_name)
            self._walk_body(st.finalbody, env, fn_unit, fn_name)
        elif isinstance(st, ast.Expr):
            self.infer(st.value, env)
        # FunctionDef/ClassDef bodies are separate scopes (run() visits
        # every def); other statements carry no unit information.

    def _bind(self, tgt: ast.AST, value_node: ast.AST,
              v: str | None, env: dict[str, str]) -> None:
        """Record a binding and run the U502 contradiction check."""
        if isinstance(tgt, ast.Name):
            t = name_unit(tgt.id)
            self._check_assign(tgt.id, t, value_node, v, tgt)
            env[tgt.id] = v if v is not UNKNOWN else (t or UNKNOWN)
        elif isinstance(tgt, ast.Attribute):
            t = name_unit(tgt.attr)
            self._check_assign(tgt.attr, t, value_node, v, tgt)
        elif isinstance(tgt, ast.Subscript):
            key = tgt.slice
            if isinstance(key, ast.Constant) and isinstance(key.value, str):
                t = suffix_unit(key.value)
                self._check_assign(f"[{key.value!r}]", t, value_node, v,
                                   tgt)
            else:
                t = self._target_unit(tgt, env)
                if t and v and t != v and DIMENSIONLESS not in (t, v):
                    self._flag("U501", tgt,
                               f"stores {v} into a {t} array",
                               "convert explicitly or fix the name")
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            elts = getattr(value_node, "elts", None) \
                if isinstance(value_node, (ast.Tuple, ast.List)) else None
            for i, sub in enumerate(tgt.elts):
                if elts is not None and i < len(elts):
                    self._bind(sub, elts[i],
                               self.infer(elts[i], env), env)
                elif isinstance(sub, ast.Name):
                    env.pop(sub.id, None)

    def _check_assign(self, tname: str, t: str | None,
                      value_node: ast.AST, v: str | None,
                      at: ast.AST) -> None:
        if t and v and t != v and DIMENSIONLESS not in (t, v):
            self._flag("U502", at,
                       f"{tname} is named as {t} but receives {v}",
                       "apply the conversion at the assignment "
                       "(* 1e3 for s→ms, / 1e6 for bytes→MB) or "
                       "rename the target")

    def _target_unit(self, tgt: ast.AST, env: dict[str, str]
                     ) -> str | None:
        if isinstance(tgt, ast.Name):
            return env.get(tgt.id) or name_unit(tgt.id)
        if isinstance(tgt, ast.Attribute):
            return name_unit(tgt.attr)
        if isinstance(tgt, ast.Subscript):
            return self._target_unit(tgt.value, env)
        return UNKNOWN

    # -- expressions -------------------------------------------------------
    def infer(self, node: ast.AST, env: dict[str, str]) -> str | None:
        """Unit of an expression; flags U501/U503/U504 as it walks."""
        if isinstance(node, ast.Name):
            u = env.get(node.id)
            return u if u is not UNKNOWN and u is not None \
                else name_unit(node.id)
        if isinstance(node, ast.Attribute):
            self.infer(node.value, env)
            return name_unit(node.attr)
        if isinstance(node, ast.Subscript):
            self.infer(node.slice, env)
            return self.infer(node.value, env)
        if isinstance(node, ast.Constant):
            return UNKNOWN       # bare literals are unitless (0.0 inits)
        if isinstance(node, ast.Call):
            return self._infer_call(node, env)
        if isinstance(node, ast.BinOp):
            return self._infer_binop(node, env)
        if isinstance(node, ast.Compare):
            return self._infer_compare(node, env)
        if isinstance(node, ast.BoolOp):
            return _join([self.infer(v, env) for v in node.values])
        if isinstance(node, ast.UnaryOp):
            return self.infer(node.operand, env)
        if isinstance(node, ast.IfExp):
            self.infer(node.test, env)
            return _join([self.infer(node.body, env),
                          self.infer(node.orelse, env)])
        if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
            return _join([self.infer(e, env) for e in node.elts])
        if isinstance(node, ast.Dict):
            self._infer_dict(node, env)
            return UNKNOWN
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            for gen in node.generators:
                self.infer(gen.iter, env)
            # the comprehension carries its element's unit
            # ([c.critical_path_s for c in chains] is a seconds array)
            return self.infer(node.elt, env)
        if isinstance(node, ast.DictComp):
            for gen in node.generators:
                self.infer(gen.iter, env)
            self.infer(node.key, env)
            self.infer(node.value, env)
            return UNKNOWN
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self.infer(v.value, env)
            return UNKNOWN
        if isinstance(node, ast.Starred):
            return self.infer(node.value, env)
        if isinstance(node, ast.Lambda):
            self.infer(node.body, {})
            return UNKNOWN
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.infer(part, env)
            return UNKNOWN
        if isinstance(node, ast.NamedExpr):
            v = self.infer(node.value, env)
            self._bind(node.target, node.value, v, env)
            return v
        return UNKNOWN

    def _infer_call(self, node: ast.Call, env: dict[str, str]
                    ) -> str | None:
        arg_units = [self.infer(a, env) for a in node.args]
        for kw in node.keywords:
            self.infer(kw.value, env)
        tail = _callee_tail(node.func)
        if isinstance(node.func, ast.Attribute):
            self.infer(node.func.value, env)
        if tail in CALL_REGISTRY:
            return CALL_REGISTRY[tail]
        if tail in _PASSTHROUGH_CALLS:
            real = [u for n, u in zip(node.args, arg_units)
                    if not (isinstance(n, ast.Constant))]
            if len(node.args) == 1 and isinstance(node.args[0],
                                                  (ast.List, ast.Tuple)):
                return arg_units[0]   # np.concatenate([a, b])
            if real:
                return _join(real)
            return arg_units[0] if arg_units else UNKNOWN
        if tail in _PASSTHROUGH_METHODS \
                and isinstance(node.func, ast.Attribute):
            return self.infer(node.func.value, env)
        u = suffix_unit(tail)    # function-name suffix → return unit
        if u is not UNKNOWN:
            return u
        return UNKNOWN

    def _opname(self, op: ast.operator | ast.cmpop) -> str:
        return {"Add": "+", "Sub": "-", "Lt": "<", "LtE": "<=",
                "Gt": ">", "GtE": ">=", "Eq": "==", "NotEq": "!=",
                }.get(type(op).__name__, type(op).__name__)

    def _infer_binop(self, node: ast.BinOp, env: dict[str, str]
                     ) -> str | None:
        left = self.infer(node.left, env)
        right = self.infer(node.right, env)
        lc = _const_class(node.left)
        rc = _const_class(node.right)

        if isinstance(node.op, (ast.Add, ast.Sub)):
            if left and right and left != right \
                    and DIMENSIONLESS not in (left, right):
                self._flag(
                    "U501", node,
                    f"{self._opname(node.op)} mixes {left} and {right}",
                    "convert one side explicitly (* 1e3 for s→ms, "
                    "/ 1e6 for bytes→MB) before combining")
                return UNKNOWN
            return _join([left, right])

        if isinstance(node.op, ast.Mult):
            for unit, const in ((left, rc), (right, lc)):
                if unit and const:
                    out = _MUL_CONV.get((unit, const))
                    if out == "U503":
                        self._flag(
                            "U503", node,
                            f"conversion factor applied to an already-"
                            f"converted value ({unit} * {const.lower()})",
                            "the value is already in the target unit; "
                            "drop the factor")
                        return UNKNOWN
                    if out:
                        return out
                    if const == _SCALAR:
                        return unit
                    return UNKNOWN
            if left and right:
                if DIMENSIONLESS in (left, right):
                    return right if left == DIMENSIONLESS else left
                out = _MUL_UNITS.get((left, right)) \
                    or _MUL_UNITS.get((right, left))
                return out or UNKNOWN
            return UNKNOWN

        if isinstance(node.op, ast.Div):
            if left and rc:
                out = _DIV_CONV.get((left, rc))
                if out == "U503":
                    self._flag(
                        "U503", node,
                        f"conversion factor applied to an already-"
                        f"converted value ({left} / {rc.lower()})",
                        "the value is already in the target unit; "
                        "drop the factor")
                    return UNKNOWN
                if out:
                    return out
                if rc == _SCALAR:
                    return left
                return UNKNOWN
            if left and right:
                if left == right:
                    return DIMENSIONLESS
                if right == DIMENSIONLESS:
                    return left
                return _DIV_UNITS.get((left, right)) or UNKNOWN
            return UNKNOWN

        return UNKNOWN     # //, %, **, <<, ... carry no unit meaning here

    def _infer_compare(self, node: ast.Compare, env: dict[str, str]
                       ) -> str | None:
        units = [self.infer(node.left, env)]
        for op, comp in zip(node.ops, node.comparators):
            u = self.infer(comp, env)
            if isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE,
                               ast.Eq, ast.NotEq)):
                prev = units[-1]
                if prev and u and prev != u \
                        and DIMENSIONLESS not in (prev, u):
                    self._flag(
                        "U501", node,
                        f"comparison {self._opname(op)} mixes {prev} "
                        f"and {u}",
                        "convert one side explicitly before comparing")
            units.append(u)
        return UNKNOWN

    # -- bench-row dicts (U502 on suffixed keys, U504 on unsuffixed) -------
    def _infer_dict(self, node: ast.Dict, env: dict[str, str]) -> None:
        keys = [k.value if isinstance(k, ast.Constant)
                and isinstance(k.value, str) else None
                for k in node.keys]
        is_bench_row = "bench" in keys
        for key, vnode in zip(keys, node.values):
            v = self.infer(vnode, env)
            if key is None:
                continue
            t = name_unit(key)
            if t is not UNKNOWN:
                self._check_assign(f'"{key}"', t, vnode, v, vnode)
            elif is_bench_row and v not in (UNKNOWN, DIMENSIONLESS):
                self._flag(
                    "U504", vnode,
                    f'bench-row key "{key}" carries a {v} value but '
                    f"does not name the unit",
                    f'suffix the key ("{key}_{v.replace("/", "_per_")}"'
                    f") so JSON consumers know the unit")


def dict_key_units(mod: Module, fn: ast.FunctionDef | None,
                   node: ast.Dict) -> dict[str, str | None]:
    """Per-key inferred units of one dict literal (for ``schemas.py``).

    Runs the silent evaluator over the enclosing function to build the
    alias environment, then infers each value expression.
    """
    ev = UnitEvaluator(mod, collect=False)
    env = ev.function_env(fn) if fn is not None else {}
    out: dict[str, str | None] = {}
    for k, vnode in zip(node.keys, node.values):
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            out[k.value] = ev.infer(vnode, env)
    return out


def check(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules:
        findings += UnitEvaluator(mod).run()
    return findings

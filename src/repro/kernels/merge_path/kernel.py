"""Merge-path sorted-run merge as a Pallas TPU kernel.

The paper's compaction hot loop is a sequential two-pointer merge — a shape
that wastes a TPU.  The TPU-native formulation used here:

* the output is tiled into 128-element blocks (the VPU lane width);
* each grid step binary-searches the **merge-path diagonal** for its tile
  over the full runs (scalar ``pl.load`` probes, O(log n));
* it then loads one 128-element window from each run into VMEM and computes
  every element's output *rank* with a [128,128] comparison-matrix count —
  rank(A_i) = i + |{j : B_j < A_i}|, rank(B_j) = j + |{i : A_i <= B_j}| —
  a pair of full-tile VPU ops instead of a data-dependent loop;
* the scatter to output positions is a masked select-sum over the same
  [128,128] tile (scatter-free, layout-friendly).

Keys are int64 split into (hi, lo) int32 planes (TPU int64 arithmetic is
emulated and slow; 2×int32 lexicographic compares are native).  Payload
seqnos ride along as a single int32 plane.  Stability: A wins ties, so
feeding runs oldest-first keeps duplicate keys seq-ascending.

Layout contract (enforced by ops.py): each run is padded to a multiple of
TILE **plus one extra TILE of +inf sentinels**, so every diagonal window
load is in bounds and "run exhausted" needs no special casing.  ``n_a`` /
``n_b`` passed to the kernel are the sentinel-exclusive padded lengths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128
HI_SENTINEL = jnp.iinfo(jnp.int32).max
LO_SENTINEL = jnp.iinfo(jnp.int32).max


def _lex_lt(a_hi, a_lo, b_hi, b_lo):
    """(a_hi, a_lo) < (b_hi, b_lo) lexicographic; lo planes are pre-biased
    (xor 0x80000000) so signed int32 compare == unsigned compare on raw."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _lex_le(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


def _merge_kernel(a_hi_ref, a_lo_ref, a_sq_ref, b_hi_ref, b_lo_ref, b_sq_ref,
                  o_hi_ref, o_lo_ref, o_sq_ref, *, n_a: int, n_b: int):
    tile = pl.program_id(0)
    k0 = tile * TILE  # global output rank of this tile's first element

    def probe(hi_ref, lo_ref, i):
        i = jnp.maximum(i, 0)
        return (pl.load(hi_ref, (pl.ds(i, 1),))[0],
                pl.load(lo_ref, (pl.ds(i, 1),))[0])

    # ---- merge-path diagonal: largest a0 with A[a0-1] <= B[k0-a0] ----------
    lo_b = jnp.maximum(0, k0 - n_b)
    hi_b = jnp.minimum(k0, n_a)
    steps = max(n_a, 1).bit_length() + 1

    def step(_, st):
        lo_b, hi_b = st
        mid = (lo_b + hi_b + 1) // 2
        a_h, a_l = probe(a_hi_ref, a_lo_ref, mid - 1)
        b_h, b_l = probe(b_hi_ref, b_lo_ref, k0 - mid)  # sentinel if == n_b
        ok = (mid == 0) | _lex_le(a_h, a_l, b_h, b_l)
        new_lo = jnp.where(ok, mid, lo_b)
        new_hi = jnp.where(ok, hi_b, mid - 1)
        active = lo_b < hi_b
        return (jnp.where(active, new_lo, lo_b),
                jnp.where(active, new_hi, hi_b))

    a0, _ = jax.lax.fori_loop(0, steps, step, (lo_b, hi_b))
    b0 = k0 - a0

    # ---- 128-wide windows (always in bounds thanks to sentinel over-pad) --
    aw_hi = pl.load(a_hi_ref, (pl.ds(a0, TILE),))
    aw_lo = pl.load(a_lo_ref, (pl.ds(a0, TILE),))
    aw_sq = pl.load(a_sq_ref, (pl.ds(a0, TILE),))
    bw_hi = pl.load(b_hi_ref, (pl.ds(b0, TILE),))
    bw_lo = pl.load(b_lo_ref, (pl.ds(b0, TILE),))
    bw_sq = pl.load(b_sq_ref, (pl.ds(b0, TILE),))

    idx = jax.lax.broadcasted_iota(jnp.int32, (TILE,), 0)

    # ---- ranks via [128,128] comparison-count (two VPU tile ops) ----------
    blt = _lex_lt(bw_hi[None, :], bw_lo[None, :], aw_hi[:, None], aw_lo[:, None])
    cnt_b_before_a = jnp.sum(blt.astype(jnp.int32), axis=1)
    ale = _lex_le(aw_hi[None, :], aw_lo[None, :], bw_hi[:, None], bw_lo[:, None])
    cnt_a_before_b = jnp.sum(ale.astype(jnp.int32), axis=1)

    r_a = idx + cnt_b_before_a          # rank within this output tile
    r_b = idx + cnt_a_before_b

    out_pos = idx
    sel_a = r_a[:, None] == out_pos[None, :]
    sel_b = r_b[:, None] == out_pos[None, :]

    def scatter(vals_a, vals_b):
        fa = jnp.sum(jnp.where(sel_a, vals_a[:, None], 0), axis=0)
        fb = jnp.sum(jnp.where(sel_b, vals_b[:, None], 0), axis=0)
        return (fa + fb).astype(jnp.int32)

    o_hi_ref[...] = scatter(aw_hi, bw_hi)
    o_lo_ref[...] = scatter(aw_lo, bw_lo)
    o_sq_ref[...] = scatter(aw_sq, bw_sq)


@functools.partial(jax.jit, static_argnames=("n_a", "n_b", "interpret"))
def merge_path_call(a_hi, a_lo, a_sq, b_hi, b_lo, b_sq, *, n_a: int,
                    n_b: int, interpret: bool = True):
    """Invoke the kernel.

    Inputs are the sentinel-padded planes of physical length ``n_a + TILE``
    / ``n_b + TILE`` where ``n_a``/``n_b`` are multiples of TILE covering
    the real run lengths.  Output has length ``n_a + n_b`` (real elements
    first, then sentinels).
    """
    assert n_a % TILE == 0 and n_b % TILE == 0
    assert a_hi.shape[0] == n_a + TILE and b_hi.shape[0] == n_b + TILE
    n_out = n_a + n_b
    grid = (n_out // TILE,)
    kernel = functools.partial(_merge_kernel, n_a=n_a, n_b=n_b)
    out_shape = [jax.ShapeDtypeStruct((n_out,), jnp.int32)] * 3
    in_spec_a = pl.BlockSpec((n_a + TILE,), lambda i: (0,))
    in_spec_b = pl.BlockSpec((n_b + TILE,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec_a] * 3 + [in_spec_b] * 3,
        out_specs=[pl.BlockSpec((TILE,), lambda i: (i,))] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(a_hi, a_lo, a_sq, b_hi, b_lo, b_sq)

"""Pure-jnp oracle for the merge-path kernel: stable two-run sorted merge.

Keys are int64 split into (hi, lo) int32 planes by the ops layer; the oracle
works on logical int64 keys directly.  Stability contract: on equal keys the
element from run A precedes the element from run B (oldest-run-first, which
keeps duplicate keys seq-ascending for the LSM's latest-wins dedup).
"""

from __future__ import annotations

import jax.numpy as jnp


def merge_two_runs_ref(a_keys: jnp.ndarray, a_seqs: jnp.ndarray,
                       b_keys: jnp.ndarray, b_seqs: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stable merge: A elements first on key ties."""
    n, m = a_keys.shape[0], b_keys.shape[0]
    keys = jnp.concatenate([a_keys, b_keys])
    seqs = jnp.concatenate([a_seqs, b_seqs])
    # stable sort on key keeps A (earlier positions) before B on ties
    order = jnp.argsort(keys, stable=True)
    del n, m
    return keys[order], seqs[order]

"""jit'd wrapper around the merge-path kernel: int64 <-> (hi, lo) planes,
sentinel padding, and the numpy convenience entry used by the LSM core's
``pallas`` merge backend."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import HI_SENTINEL, LO_SENTINEL, TILE, merge_path_call

_BIAS = np.int64(0x8000_0000)


def split_planes(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """int64 key -> (hi, lo) int32 planes with order-preserving lo bias.

    hi = key >> 32 (arithmetic); lo = bit-reinterpret((key & 0xffffffff)
    ^ 0x80000000) so a *signed* int32 compare on lo matches the unsigned
    compare on the raw low word; (hi, lo) lexicographic == int64 order.
    """
    keys = np.asarray(keys, np.int64)
    hi = (keys >> 32).astype(np.int32)
    raw = (keys & 0xFFFF_FFFF).astype(np.uint32)
    lo = (raw ^ np.uint32(0x8000_0000)).view(np.int32)
    return hi, np.ascontiguousarray(lo)


def join_planes(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    hi = np.asarray(hi, np.int64)
    raw = (np.ascontiguousarray(np.asarray(lo, np.int32)).view(np.uint32)
           ^ np.uint32(0x8000_0000)).astype(np.int64)
    return (hi << 32) | raw


def _pad_run(hi: np.ndarray, lo: np.ndarray, sq: np.ndarray
             ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    n = hi.shape[0]
    n_pad = max(TILE, ((n + TILE - 1) // TILE) * TILE)
    total = n_pad + TILE  # one extra sentinel tile for window loads
    def pad(x, fill):
        out = np.full(total, fill, np.int32)
        out[:n] = x
        return out
    return (pad(hi, HI_SENTINEL), pad(lo, LO_SENTINEL),
            pad(sq, 0), n_pad)


def merge_two_runs_np(a_keys: np.ndarray, a_seqs: np.ndarray,
                      b_keys: np.ndarray, b_seqs: np.ndarray,
                      interpret: bool = True
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Stable merge of two sorted int64 runs via the TPU kernel
    (interpret mode on CPU).  Seqnos must fit int32."""
    n, m = int(a_keys.shape[0]), int(b_keys.shape[0])
    if n == 0:
        return np.asarray(b_keys, np.int64), np.asarray(b_seqs, np.int64)
    if m == 0:
        return np.asarray(a_keys, np.int64), np.asarray(a_seqs, np.int64)
    assert np.all(np.abs(a_seqs) < 2**31) and np.all(np.abs(b_seqs) < 2**31)
    a_hi, a_lo = split_planes(a_keys)
    b_hi, b_lo = split_planes(b_keys)
    a_hi, a_lo, a_sq, n_a = _pad_run(a_hi, a_lo, np.asarray(a_seqs, np.int32))
    b_hi, b_lo, b_sq, n_b = _pad_run(b_hi, b_lo, np.asarray(b_seqs, np.int32))
    o_hi, o_lo, o_sq = merge_path_call(
        jnp.asarray(a_hi), jnp.asarray(a_lo), jnp.asarray(a_sq),
        jnp.asarray(b_hi), jnp.asarray(b_lo), jnp.asarray(b_sq),
        n_a=n_a, n_b=n_b, interpret=interpret)
    keys = join_planes(np.asarray(o_hi), np.asarray(o_lo))[:n + m]
    seqs = np.asarray(o_sq, np.int64)[:n + m]
    return keys, seqs

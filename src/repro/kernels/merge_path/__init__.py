from . import ops, ref
from .kernel import TILE, merge_path_call

__all__ = ["TILE", "merge_path_call", "ops", "ref"]

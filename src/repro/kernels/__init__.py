# Pallas TPU kernels for the perf-critical compute layers.
#
# Paper hot-spots:
#   merge_path    — compaction sorted-run merge (merge-path diagonal tiling)
#   overlap_scan  — §4.2 per-key L2-fence overlap probes (batched counts)
#   lindley_scan  — DES FIFO-queue departure recursion (blocked max-plus
#                   scan, batched over shards / sweep points)
# Framework hot-spots:
#   flash_attention — blockwise train/prefill attention (causal/window/GQA)
#   paged_attention — decode over the LSM-managed KV page pool
#   ssd_scan        — Mamba2 SSD chunked scan
#
# Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py
# (jit'd wrapper) and ref.py (pure-jnp oracle).  Kernels are validated in
# interpret=True mode on CPU; TPU is the target.  Import lazily — these pull
# in jax.

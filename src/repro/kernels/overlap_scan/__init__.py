from . import ops, ref
from .kernel import TILE, fence_rank_call

__all__ = ["TILE", "fence_rank_call", "ops", "ref"]

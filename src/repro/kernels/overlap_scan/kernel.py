"""Batched fence-pointer rank counts as a Pallas TPU kernel.

The paper's §4.2 look-ahead policy probes, for *every appended key*, the
overlap of the in-flight vSST with the L2 fence table — the per-key CPU
hot-spot the authors call out in §6.3.  A GPU port would binary-search per
thread; the TPU-native shape is **brute-force block counting**: a [128
keys × 128 fences] comparison tile is a single VPU op, so counting
``#fences <= key`` over fence tiles beats a gather-heavy binary search for
fence tables up to tens of thousands of entries (and the ops layer falls
back to hierarchical pre-slicing beyond that).

Keys/fences are int64 split into (hi, lo) int32 planes (same convention as
``merge_path``).  Grid: (key tiles,); fences live whole in VMEM; the kernel
loops fence tiles with a fori_loop accumulating int32 counts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _lex_le(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


def _rank_kernel(f_hi_ref, f_lo_ref, k_hi_ref, k_lo_ref, out_ref,
                 *, n_fences: int):
    k_hi = k_hi_ref[...]
    k_lo = k_lo_ref[...]
    n_tiles = n_fences // TILE

    def body(t, acc):
        f_hi = pl.load(f_hi_ref, (pl.ds(t * TILE, TILE),))
        f_lo = pl.load(f_lo_ref, (pl.ds(t * TILE, TILE),))
        # fence <= key, [keys=128, fences=128] tile
        le = _lex_le(f_hi[None, :], f_lo[None, :], k_hi[:, None], k_lo[:, None])
        return acc + jnp.sum(le.astype(jnp.int32), axis=1)

    counts = jax.lax.fori_loop(0, n_tiles, body,
                               jnp.zeros((TILE,), jnp.int32))
    out_ref[...] = counts


@functools.partial(jax.jit, static_argnames=("n_fences", "interpret"))
def fence_rank_call(f_hi, f_lo, k_hi, k_lo, *, n_fences: int,
                    interpret: bool = True):
    """counts[i] = #{j < n_fences : fence_j <= key_i}.

    Planes must be padded to TILE multiples; fence padding must be +inf
    sentinels (they never count, being > any real key... they *would*
    count for sentinel keys, which the ops layer slices away).
    """
    assert n_fences % TILE == 0 and f_hi.shape[0] == n_fences
    n_keys = k_hi.shape[0]
    assert n_keys % TILE == 0
    kernel = functools.partial(_rank_kernel, n_fences=n_fences)
    return pl.pallas_call(
        kernel,
        grid=(n_keys // TILE,),
        in_specs=[
            pl.BlockSpec((n_fences,), lambda i: (0,)),
            pl.BlockSpec((n_fences,), lambda i: (0,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_keys,), jnp.int32),
        interpret=interpret,
    )(f_hi, f_lo, k_hi, k_lo)

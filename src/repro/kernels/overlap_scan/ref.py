"""Pure-jnp oracle for overlap_scan: batched fence-pointer rank counts.

For each query key, the number of fence values <= key (i.e.
``jnp.searchsorted(fences, keys, side='right')``).  The vSST look-ahead
policy derives its per-key L2 overlap from exactly this count (§4.2: the
overlap of [k_lo, k_hi] is rank_right(fence_lo, k_hi) - rank_left(fence_hi,
k_lo)).
"""

from __future__ import annotations

import jax.numpy as jnp


def fence_rank_ref(fences: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    return jnp.searchsorted(fences, keys, side="right").astype(jnp.int32)

"""jit'd wrapper for overlap_scan: plane splitting, padding, numpy entry."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.merge_path.ops import join_planes, split_planes  # noqa: F401
from .kernel import TILE, fence_rank_call

_HI_SENT = np.int32(np.iinfo(np.int32).max)


def _pad_planes(hi: np.ndarray, lo: np.ndarray, fill_hi, fill_lo
                ) -> tuple[np.ndarray, np.ndarray, int]:
    n = hi.shape[0]
    n_pad = max(TILE, ((n + TILE - 1) // TILE) * TILE)
    H = np.full(n_pad, fill_hi, np.int32)
    L = np.full(n_pad, fill_lo, np.int32)
    H[:n] = hi
    L[:n] = lo
    return H, L, n_pad


def fence_rank_np(fences: np.ndarray, keys: np.ndarray,
                  interpret: bool = True) -> np.ndarray:
    """#fences <= key, per key (== np.searchsorted(fences, keys, 'right'))."""
    if fences.shape[0] == 0:
        return np.zeros(keys.shape[0], np.int32)
    f_hi, f_lo = split_planes(np.asarray(fences, np.int64))
    k_hi, k_lo = split_planes(np.asarray(keys, np.int64))
    f_hi, f_lo, n_f = _pad_planes(f_hi, f_lo, _HI_SENT, _HI_SENT)
    k_hi, k_lo, _ = _pad_planes(k_hi, k_lo, _HI_SENT, _HI_SENT)
    out = fence_rank_call(jnp.asarray(f_hi), jnp.asarray(f_lo),
                          jnp.asarray(k_hi), jnp.asarray(k_lo),
                          n_fences=n_f, interpret=interpret)
    return np.asarray(out)[:keys.shape[0]]


def fence_rank_strict_np(fences: np.ndarray, keys: np.ndarray,
                         interpret: bool = True) -> np.ndarray:
    """#fences < key, per key (== np.searchsorted(fences, keys, 'left')).

    Integer keys only: the strict rank is the inclusive rank of ``key - 1``.
    This is the second primitive ``repro.core.level_index`` needs for its
    ``pallas`` backend (start-of-overlap = strict rank of ``lo`` over the
    level's ``largest`` fences).
    """
    return fence_rank_np(fences, np.asarray(keys, np.int64) - 1, interpret)


def overlap_counts_np(fence_lo: np.ndarray, fence_hi: np.ndarray,
                      key_lo: np.ndarray, key_hi: np.ndarray,
                      interpret: bool = True) -> np.ndarray:
    """Vectorized §4.2 overlap: #L2 SSTs intersecting each [key_lo, key_hi]
    candidate vSST range = rank_right(fence_lo, key_hi) -
    rank_right_strict(fence_hi, key_lo)."""
    last = fence_rank_np(fence_lo, key_hi, interpret)
    # rank of fence_hi STRICTLY below key_lo == #fences <= key_lo - 1
    first = fence_rank_np(fence_hi, key_lo - 1, interpret)
    return np.maximum(0, last - first)

"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The sequential recurrence is bandwidth- and latency-bound; SSD's insight is
that a length-CK chunk can be computed as dense matmuls (the "duality with
attention") with only the chunk-boundary state carried sequentially:

    a_cs[t]  = A * cumsum(dt)[t]                       (within chunk)
    y_intra  = (tril(C B^T ⊙ exp(a_cs_t - a_cs_j)) ⊙ dt_j) @ X    [CK,CK]@[CK,P]
    y_inter  = exp(a_cs_t) * (C @ S_prev)                          [CK,N]@[N,P]
    S_new    = exp(a_cs_last) S_prev + (B ⊙ exp(a_cs_last - a_cs) dt)^T @ X

Both heavy terms are MXU matmuls; the state S [N, P] lives in VMEM scratch
across the chunk sweep (grid minor dimension).  A is negative and dt > 0,
so every exp() argument is <= 0 — numerically safe without rescaling.

Grid: (BH, L // CK).  Exactness: this *is* the reference recurrence
refactored (no approximation), so the test tolerance is float-roundoff.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CK = 128


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_ref,
                *, ck: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[0].astype(jnp.float32)                 # scalar per head
    x = x_ref[0].astype(jnp.float32)                 # [CK, P]
    dt = dt_ref[0].astype(jnp.float32)               # [CK]
    b = b_ref[0].astype(jnp.float32)                 # [CK, N]
    c = c_ref[0].astype(jnp.float32)                 # [CK, N]

    a_cs = a * jnp.cumsum(dt)                        # [CK], inclusive
    s_prev = s_ref[...]                              # [N, P]

    # inter-chunk: y_t += exp(a_cs_t) * C_t @ S_prev
    cs = jax.lax.dot_general(c, s_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [CK, P]
    y = jnp.exp(a_cs)[:, None] * cs

    # intra-chunk: y_t += sum_{j<=t} exp(a_cs_t - a_cs_j) dt_j (C_t.B_j) x_j
    cb_mat = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [CK,CK]
    ti = jax.lax.broadcasted_iota(jnp.int32, (ck, ck), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (ck, ck), 1)
    decay = jnp.exp(a_cs[:, None] - a_cs[None, :])   # [t, j]
    w = jnp.where(tj <= ti, cb_mat * decay * dt[None, :], 0.0)
    y = y + jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S_new = exp(a_cs_last) S_prev + (B ⊙ w_j)^T @ X
    wj = jnp.exp(a_cs[-1] - a_cs) * dt               # [CK]
    bw = b * wj[:, None]                             # [CK, N]
    s_ref[...] = (jnp.exp(a_cs[-1]) * s_prev
                  + jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ck", "interpret"))
def ssd_scan_call(x, dt, a, b, c, *, ck: int = DEFAULT_CK,
                  interpret: bool = True):
    """x: [BH, L, P]; dt: [BH, L]; a: [BH]; b, c: [BH, L, N] -> [BH, L, P]."""
    bh, L, p = x.shape
    n = b.shape[-1]
    assert L % ck == 0, f"L={L} must be a multiple of ck={ck}"
    kernel = functools.partial(_ssd_kernel, ck=ck)
    return pl.pallas_call(
        kernel,
        grid=(bh, L // ck),
        in_specs=[
            pl.BlockSpec((1,), lambda h, t: (h,)),
            pl.BlockSpec((1, ck, p), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, ck), lambda h, t: (h, t)),
            pl.BlockSpec((1, ck, n), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, ck, n), lambda h, t: (h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, ck, p), lambda h, t: (h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, L, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(a, x, dt, b, c)

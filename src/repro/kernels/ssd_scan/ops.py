"""jit'd wrapper: [B, L, H, P] model-layout API with chunk padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_CK, ssd_scan_call


@functools.partial(jax.jit, static_argnames=("ck", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, *, ck: int = DEFAULT_CK,
             interpret: bool = True) -> jnp.ndarray:
    """x: [B, L, H, P]; dt: [B, L, H]; a: [H]; b, c: [B, L, G, N] with
    H % G == 0 -> y: [B, L, H, P]."""
    bsz, L, h, p = x.shape
    g = b.shape[2]
    rep = h // g
    bf = jnp.repeat(b, rep, axis=2)                     # [B, L, H, N]
    cf = jnp.repeat(c, rep, axis=2)
    ckk = min(ck, L) if L % ck else ck
    pad = (-L) % ckk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bf = jnp.pad(bf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cf = jnp.pad(cf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    n = bf.shape[-1]
    xh = x.transpose(0, 2, 1, 3).reshape(bsz * h, Lp, p)
    dth = dt.transpose(0, 2, 1).reshape(bsz * h, Lp)
    bh_ = bf.transpose(0, 2, 1, 3).reshape(bsz * h, Lp, n)
    ch_ = cf.transpose(0, 2, 1, 3).reshape(bsz * h, Lp, n)
    ah = jnp.tile(a, bsz)
    y = ssd_scan_call(xh, dth, ah, bh_, ch_, ck=ckk, interpret=interpret)
    y = y.reshape(bsz, h, Lp, p).transpose(0, 2, 1, 3)
    return y[:, :L]

from . import ops, ref
from .kernel import ssd_scan_call
from .ops import ssd_scan

__all__ = ["ssd_scan", "ssd_scan_call", "ops", "ref"]

"""Pure-jnp oracle for the Mamba2 SSD scan: the sequential recurrence.

Discretization (Mamba2, per head):
    s_t = exp(dt_t * A) * s_{t-1} + dt_t * B_t x_t^T        s in R^{N x P}
    y_t = C_t^T s_t                                          y in R^P

A is a scalar per head (negative); dt_t > 0 (softplus upstream); B_t, C_t
in R^N; x_t in R^P.  The D-skip and gating live in the model layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                 b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """x: [BH, L, P]; dt: [BH, L]; a: [BH]; b, c: [BH, L, N] -> [BH, L, P]."""
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    a32 = a.astype(jnp.float32)

    def per_head(x_h, dt_h, a_h, b_h, c_h):
        n, p = b_h.shape[-1], x_h.shape[-1]

        def step(s, inp):
            xt, dtt, bt, ct = inp
            lam = jnp.exp(dtt * a_h)
            s = lam * s + dtt * (bt[:, None] * xt[None, :])
            y = ct @ s
            return s, y

        s0 = jnp.zeros((n, p), jnp.float32)
        _, ys = jax.lax.scan(step, s0, (x_h, dt_h, b_h, c_h))
        return ys

    ys = jax.vmap(per_head)(x32, dt32, a32, b32, c32)
    return ys.astype(x.dtype)

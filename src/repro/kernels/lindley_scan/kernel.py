"""Blocked Lindley (max-plus) scan as a Pallas TPU kernel.

The recursion D_j = S_j + max(d0, max_{k<=j}(a_k - S_{k-1})) decomposes
over fixed-size tiles exactly like any prefix scan: a tile computes its
local inclusive cumsum and running max, then folds in two scalar carries
from the tiles before it — the accumulated service sum ``s_off`` and the
running max-plus state ``m``.  Both carries live in SMEM scratch across
the minor grid dimension (same carry pattern as ``ssd_scan``'s VMEM
state), initialised at tile 0 from the per-row ``d0``.

Grid: (B rows, N // TILE).  Exactness: this *is* the reference recursion
refactored tile-wise — no approximation; the only divergence from the
monolithic numpy pass is cumsum re-association across tile boundaries
(float64 roundoff, ~1e-12 relative at DES scales).

float64 throughout: absolute simulated times (~1e2 s) against
microsecond latencies leave float32 with zero significant bits in the
tail.  Interpret mode executes f64 fine on CPU; a real-TPU deployment
would rebase each row to its window start and keep f32 carries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


def _lindley_kernel(d0_ref, s_ref, a_ref, out_ref, carry_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[0] = 0.0          # s_off: service sum of prior tiles
        carry_ref[1] = d0_ref[0]    # m: running max-plus state

    s = s_ref[0]                    # [TILE]
    a = a_ref[0]                    # [TILE]
    local = jnp.cumsum(s)
    shifted = jnp.concatenate([jnp.zeros((1,), local.dtype), local[:-1]])
    g = a - (carry_ref[0] + shifted)
    m_run = jnp.maximum(jax.lax.cummax(g), carry_ref[1])
    out_ref[0] = carry_ref[0] + local + m_run
    carry_ref[0] = carry_ref[0] + local[-1]
    carry_ref[1] = m_run[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def lindley_scan_call(service, arrivals, d0, *, interpret: bool = True):
    """service, arrivals: [B, N] float64 (N a TILE multiple); d0: [B]
    float64 -> departures [B, N].  Pad rows with service 0 / arrival -inf
    (a -inf G term never wins the running max)."""
    b, n = service.shape
    assert n % TILE == 0, f"N={n} must be a multiple of TILE={TILE}"
    assert arrivals.shape == (b, n) and d0.shape == (b,)
    return pl.pallas_call(
        _lindley_kernel,
        grid=(b, n // TILE),
        in_specs=[
            pl.BlockSpec((1,), lambda i, t: (i,)),
            pl.BlockSpec((1, TILE), lambda i, t: (i, t)),
            pl.BlockSpec((1, TILE), lambda i, t: (i, t)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i, t: (i, t)),
        out_shape=jax.ShapeDtypeStruct((b, n), service.dtype),
        scratch_shapes=[pltpu.SMEM((2,), service.dtype)],
        interpret=interpret,
    )(d0, service, arrivals)

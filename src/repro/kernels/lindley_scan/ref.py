"""Pure-jnp oracle for lindley_scan: the FIFO-queue departure recursion.

The DES advances each shard's processed clock with the Lindley recursion

    D_j = S_j + max(D_prev, max_{k<=j}(a_k - S_{k-1})),   S_j = cumsum(s)_j

(``Simulator._advance_clock`` / the final per-shard accounting pass in
``Simulator.run``).  Writing G_j = a_j - S_{j-1} this is an associative
max-plus scan: D_j = S_j + max(d0, cummax(G)_j), with d0 = -inf for a
queue observed from its first arrival.  The oracle computes exactly that
in float64 — absolute simulated times run to hundreds of seconds while
latencies are microseconds, so float32 would destroy the tail.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lindley_ref(service: jnp.ndarray, arrivals: jnp.ndarray,
                d0: float = -jnp.inf) -> jnp.ndarray:
    """Departure times of one FIFO queue: ``service``/``arrivals`` are
    1-D, same length, float64; ``d0`` is the departure clock carried in
    from an earlier window (-inf: no prior history)."""
    s_cum = jnp.cumsum(service)
    shifted = jnp.concatenate([jnp.zeros((1,), s_cum.dtype), s_cum[:-1]])
    g = arrivals - shifted
    m = jnp.maximum(jax.lax.cummax(g), d0)
    return s_cum + m


# Batched rows: [B, N] service/arrivals, [B] d0 -> [B, N] departures.
# THE vmap axis of the fleet engine: every (policy, config, shard) queue
# in a sweep matrix is one row of this single batched program.  jit so
# the whole batch compiles to ONE fused program instead of dispatching
# eagerly per primitive (sweep matrices hit the same padded shape, so
# the compile is paid once per shape).
lindley_ref_batch = jax.jit(jax.vmap(lindley_ref, in_axes=(0, 0, 0)))

"""Numpy entry points for lindley_scan: x64 scope, padding, ragged batch.

The DES hands over ragged per-queue (service, arrivals) arrays — one row
per shard, or per (policy, config, shard) point of a whole sweep matrix.
``lindley_batch_np`` pads them into ONE [B, N] program (pallas blocked
scan, or the vmapped jnp oracle) and slices the departures back out; the
fleet engine's final latency accounting is exactly one such call.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from .kernel import TILE, lindley_scan_call

_NEG_INF = float("-inf")

# Pad-plan cache, keyed by the batch's length tuple.  A load curve (and
# every executor cache hit) evaluates the same queue SHAPES at each grid
# point — arrivals change, lengths do not — so the power-of-two bucket
# map and the padded (S, A) buffers are reused across calls instead of
# being rebuilt/refilled every factor.  The pad regions' fill (0 service
# / -inf arrival) is shape-invariant, so reused buffers only need their
# real-data prefixes rewritten; results are byte-identical to a fresh
# allocation.  Bounded LRU: entries hold [b, n_pad] float64 buffers.
_plan_cache: OrderedDict = OrderedDict()
_PLAN_CACHE_MAX = 32
# numpy-tier scratch (c_buf, g_buf), grown monotonically: shared across
# calls for the same first-touch-avoidance reason.
_np_scratch: list[np.ndarray] = [np.empty(0, np.float64),
                                 np.empty(0, np.float64)]


def _pad_plan(lens: tuple[int, ...]) -> list[tuple]:
    """The cached padding plan for one batch shape: a list of
    ``(n_pad, idxs, S, A)`` per occupied power-of-two bucket."""
    plan = _plan_cache.get(lens)
    if plan is not None:
        _plan_cache.move_to_end(lens)
        return plan
    buckets: dict[int, list[int]] = {}
    for i, ln in enumerate(lens):
        if ln == 0:
            continue
        n_pad = TILE
        while n_pad < ln:
            n_pad *= 2
        buckets.setdefault(n_pad, []).append(i)
    plan = []
    for n_pad, idxs in sorted(buckets.items()):
        S = np.zeros((len(idxs), n_pad), np.float64)
        # -inf arrival padding: the padded G terms never win the running
        # max, so real departures are unaffected and pad outputs are
        # sliced away.
        A = np.full((len(idxs), n_pad), _NEG_INF, np.float64)
        plan.append((n_pad, idxs, S, A))
    _plan_cache[lens] = plan
    while len(_plan_cache) > _PLAN_CACHE_MAX:
        _plan_cache.popitem(last=False)
    return plan


def clear_pad_plans() -> None:
    """Drop the cached pad plans and numpy scratch (tests / memory)."""
    _plan_cache.clear()
    _np_scratch[0] = np.empty(0, np.float64)
    _np_scratch[1] = np.empty(0, np.float64)


def lindley_batch_np(services: list[np.ndarray], arrivals: list[np.ndarray],
                     d0: list[float] | None = None,
                     backend: str = "pallas",
                     interpret: bool = True) -> list[np.ndarray]:
    """Departure times for a ragged batch of FIFO queues.

    ``services[i]``/``arrivals[i]`` are queue i's per-op service times and
    arrival times (1-D, equal length, possibly empty); ``d0[i]`` the
    carried-in departure clock (default -inf: fresh queue).  Returns the
    per-queue departure arrays.  ``backend``: "pallas" (blocked-scan
    kernel, interpret mode on CPU), "jnp" (vmapped oracle), or "numpy"
    (:func:`lindley_numpy` per queue — no padding, no device: XLA's CPU
    lowering serializes cumulative scans at ~20x numpy's throughput and
    the padded batch costs ~2x extra memory traffic, so this is the
    CPU-tier choice for large sweep matrices; all three are asserted
    equal in the kernel tests).

    Very ragged batches (a sweep mixing 1-shard and 16-shard queues) are
    padded in power-of-two length *buckets* rather than to the single
    global max: one device program per occupied bucket, each [b_i, n_i]
    with <2x pad waste, instead of one [B, n_max] program that would
    inflate every short queue to the longest.
    """
    assert backend in ("pallas", "jnp", "numpy")
    b = len(services)
    assert len(arrivals) == b
    if d0 is None:
        d0 = [_NEG_INF] * b
    lens = [int(s.shape[0]) for s in services]
    if max(lens, default=0) == 0:
        return [np.empty(0, np.float64) for _ in range(b)]
    if backend == "numpy":
        # lindley_numpy per queue, but with two scratch buffers shared
        # across the batch AND across calls (module scratch, grown
        # monotonically): fresh first-touch allocations dominate the
        # plain per-queue loop on big matrices, and only the departure
        # array escapes.  Operation order matches lindley_numpy exactly
        # (bit-identical results — the parity anchor).
        nmax = max(lens)
        if _np_scratch[0].shape[0] < nmax:
            _np_scratch[0] = np.empty(nmax, np.float64)
            _np_scratch[1] = np.empty(nmax, np.float64)
        c_buf, g_buf = _np_scratch
        outs = []
        for s, a, d, ln in zip(services, arrivals, d0, lens):
            if ln == 0:
                outs.append(np.empty(0, np.float64))
                continue
            cc, gg = c_buf[:ln], g_buf[:ln]
            np.cumsum(np.asarray(s, np.float64), out=cc)
            np.copyto(gg, a)
            gg[1:] -= cc[:-1]
            np.maximum(gg, d, out=gg)
            np.maximum.accumulate(gg, out=gg)
            outs.append(cc + gg)
        return outs
    # bucket i by padded length: TILE * 2^ceil(log2(len/TILE)) — the
    # plan (bucket map + padded buffers) is cached across calls
    out: list[np.ndarray | None] = [np.empty(0, np.float64)] * b
    import jax
    with jax.experimental.enable_x64():
        for n_pad, idxs, S, A in _pad_plan(tuple(lens)):
            for row, i in enumerate(idxs):
                S[row, :lens[i]] = services[i]
                A[row, :lens[i]] = arrivals[i]
            D0 = np.asarray([d0[i] for i in idxs], np.float64)
            if backend == "pallas":
                dep = lindley_scan_call(S, A, D0, interpret=interpret)
            else:
                from .ref import lindley_ref_batch
                dep = lindley_ref_batch(S, A, D0)
            dep = np.asarray(dep, np.float64)
            for row, i in enumerate(idxs):
                out[i] = dep[row, :lens[i]]
    return out


def lindley_np(service: np.ndarray, arrivals: np.ndarray,
               d0: float = _NEG_INF, backend: str = "pallas",
               interpret: bool = True) -> np.ndarray:
    """Single-queue convenience wrapper over :func:`lindley_batch_np`."""
    return lindley_batch_np([np.asarray(service, np.float64)],
                            [np.asarray(arrivals, np.float64)],
                            [d0], backend=backend, interpret=interpret)[0]


def lindley_numpy(service: np.ndarray, arrivals: np.ndarray,
                  d0: float = _NEG_INF) -> np.ndarray:
    """The monolithic numpy recursion — bit-identical to the DES's
    per-shard accounting pass in ``Simulator.run`` (the parity anchor the
    kernel tests compare both backends against)."""
    s = np.asarray(service, np.float64)
    a = np.asarray(arrivals, np.float64)
    if s.shape[0] == 0:
        return np.empty(0, np.float64)
    s_cum = np.cumsum(s)
    base = a.copy()
    base[1:] -= s_cum[:-1]
    return s_cum + np.maximum.accumulate(np.maximum(base, d0))

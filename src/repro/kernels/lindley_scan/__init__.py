from . import ops, ref
from .kernel import TILE, lindley_scan_call

__all__ = ["TILE", "lindley_scan_call", "ops", "ref"]

"""Paged decode attention as a Pallas TPU kernel (scalar-prefetch gather).

The serving layer stores KV-cache blocks in a page pool indexed by the
LSM-backed prefix cache (``repro.serving``).  Decode attention must gather a
sequence's pages by page-table indirection — on TPU the idiomatic form is
**scalar prefetch**: the page table rides in SMEM ahead of the grid, and
each grid step's BlockSpec index_map picks the right page out of HBM, so
page loads are regular async copies instead of data-dependent gathers.

Grid: ``(batch, kv_heads, max_pages)`` with online-softmax accumulators in
VMEM scratch across the page sweep.  Q heads are grouped per KV head
([G, D] tile, G = Hq/Hkv) so GQA costs one MXU op per page per group.
Pages past a sequence's length are skipped with ``pl.when`` — decode cost
tracks the *true* cache length, not the padded maximum (same property the
vLSM store gives compaction: work ∝ live data).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(page_table_ref, lengths_ref,   # scalar prefetch (SMEM)
                  q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref,
                  *, page_size: int, max_pages: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    n_pages = (length + page_size - 1) // page_size

    @pl.when(p < n_pages)
    def _work():
        q = q_ref[0, 0].astype(jnp.float32)       # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)    # [PS, D]
        v = v_ref[0, :, 0].astype(jnp.float32)    # [PS, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_size), 1)
        mask = tok < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pr = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            alpha * l_ref[...][:, :1] + jnp.sum(pr, axis=1, keepdims=True),
            l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(p == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...][:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention_call(q, k_pages, v_pages, page_table, lengths, *,
                         scale: float | None = None, interpret: bool = True):
    """q: [B, Hkv, G, D] (grouped); pages: [NP, PS, Hkv, D];
    page_table: [B, MAXP] int32; lengths: [B] int32 -> [B, Hkv, G, D]."""
    b, hkv, g, d = q.shape
    np_, ps, hkv2, _ = k_pages.shape
    assert hkv2 == hkv
    maxp = page_table.shape[1]
    scale = scale if scale is not None else d ** -0.5
    kernel = functools.partial(_paged_kernel, page_size=ps, max_pages=maxp,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, p, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, d), lambda b, h, p, pt, ln: (pt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, d), lambda b, h, p, pt, ln: (pt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, p, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)

"""Pure-jnp oracle for paged decode attention.

Gathers each sequence's pages into a contiguous KV view, then computes
single-token attention with a length mask.  GQA grouping: q heads are
grouped per KV head.
"""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, page_table: jnp.ndarray,
                        lengths: jnp.ndarray,
                        scale: float | None = None) -> jnp.ndarray:
    """q: [B, Hq, D]; k_pages/v_pages: [NP, PS, Hkv, D];
    page_table: [B, MAXP] int32; lengths: [B] int32 -> [B, Hq, D]."""
    b, hq, d = q.shape
    np_, ps, hkv, _ = k_pages.shape
    maxp = page_table.shape[1]
    scale = scale if scale is not None else d ** -0.5

    k = k_pages[page_table]            # [B, MAXP, PS, Hkv, D]
    v = v_pages[page_table]
    k = k.reshape(b, maxp * ps, hkv, d)
    v = v.reshape(b, maxp * ps, hkv, d)
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)     # [B, T, Hq, D]
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    t_idx = jnp.arange(maxp * ps)[None, None, :]
    mask = t_idx < lengths[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bht,bthd->bhd", p, v.astype(jnp.float32))
    out /= jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return out.astype(q.dtype)

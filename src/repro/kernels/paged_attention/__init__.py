from . import ops, ref
from .kernel import paged_attention_call
from .ops import paged_attention

__all__ = ["paged_attention", "paged_attention_call", "ops", "ref"]

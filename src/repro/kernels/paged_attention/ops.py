"""jit'd wrapper: ungrouped [B, Hq, D] API over the grouped kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import paged_attention_call


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, page_table: jnp.ndarray,
                    lengths: jnp.ndarray, *, scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, D]; pages: [NP, PS, Hkv, D] -> [B, Hq, D]."""
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    out = paged_attention_call(qg, k_pages, v_pages,
                               page_table.astype(jnp.int32),
                               lengths.astype(jnp.int32),
                               scale=scale, interpret=interpret)
    return out.reshape(b, hq, d)

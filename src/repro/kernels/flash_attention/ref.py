"""Pure-jnp oracle for flash attention: full-materialization softmax
attention with causal / sliding-window masks and GQA head grouping."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jnp.ndarray:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out /= jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return out.astype(q.dtype)

"""Blockwise (flash) attention as a Pallas TPU kernel.

Grid layout (MaxText-style): ``(batch*heads, q_blocks, k_blocks)`` with the
KV dimension minor-most so the fp32 accumulator, running max and running
denominator live in VMEM scratch across the KV sweep (TPU grid steps on the
same core reuse scratch).  Per step:

* load Q [BQ, D] (revisited across k steps — Pallas keeps the block in VMEM
  since the index map is constant in ``kb``), K/V [BK, D];
* S = Q @ K^T  (MXU, fp32 accumulate), masked for causal / sliding window;
* online softmax rescale (running max ``m`` and sum ``l`` as [BQ, 128]
  lanes-replicated tiles, the TPU-friendly layout for rowwise stats);
* ACC += P @ V (MXU); final step writes ``ACC / l`` to the output block.

Fully-masked blocks are skipped with ``pl.when`` (causal upper triangle and
out-of-window diagonals), so wall-clock tracks the true mask density.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, bq: int, bk: int, n_kb: int, causal: bool,
                  window: int | None, scale: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qb * bq
    k_start = kb * bk

    # Block-level mask reachability: causal needs k_start <= q_end; window
    # needs k_end > q_start - window.
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window is not None:
        reachable = jnp.logical_and(reachable,
                                    k_start + bk - 1 > q_start - window)

    @pl.when(reachable)
    def _work():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= (qi - kj) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, :1]                      # [BQ, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)       # [BQ, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # [BQ, BK]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                 # [BQ, 1]
        l_new = alpha * l_ref[...][:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention_call(q, k, v, *, causal: bool = True,
                         window: int | None = None,
                         scale: float | None = None,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         interpret: bool = True):
    """q, k, v: [BH, S, D] (heads pre-flattened, kv pre-repeated to Hq)."""
    bh, s, d = q.shape
    assert k.shape == (bh, s, d) and v.shape == (bh, s, d)
    assert s % bq == 0 and s % bk == 0
    scale = scale if scale is not None else d ** -0.5
    n_kb = s // bk
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kb=n_kb, causal=causal,
        window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bq, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, bk, d), lambda h, qb, kb: (h, kb, 0)),
            pl.BlockSpec((1, bk, d), lambda h, qb, kb: (h, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qb, kb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

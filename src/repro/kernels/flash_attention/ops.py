"""jit'd wrapper: [B, H, S, D] API with GQA repeat + padding to block size."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BK, DEFAULT_BQ, flash_attention_call


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D]; returns [B, Hq, S, D]."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = min(DEFAULT_BQ, s)
    bk = min(DEFAULT_BK, s)
    pad = (-s) % max(bq, bk)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    sp = s + pad
    # padding keys must never win the softmax: causal masking already blocks
    # future positions for padded queries; for non-causal, mask via window of
    # the padded tail is unnecessary because we slice padded queries away and
    # padded KEYS contribute exp(0·) terms — so push their logits down by
    # making padded K rows large-negative via a length mask in the kernel
    # would be needed. We instead rely on causal=True for all padded uses
    # and assert here.
    assert causal or pad == 0, "non-causal padding unsupported; pad upstream"
    out = flash_attention_call(
        qp.reshape(b * hq, sp, d), kp.reshape(b * hq, sp, d),
        vp.reshape(b * hq, sp, d), causal=causal, window=window,
        scale=scale, interpret=interpret)
    out = out.reshape(b, hq, sp, d)
    return out[:, :, :s]

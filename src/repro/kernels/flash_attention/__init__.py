from . import ops, ref
from .kernel import flash_attention_call
from .ops import flash_attention

__all__ = ["flash_attention", "flash_attention_call", "ops", "ref"]

"""Open-loop traffic layer over the per-shard Lindley queues.

Converts the engine from a replay tool into a service model: a
:class:`TrafficSpec` names a set of tenants — each a workload mix, an
offered rate, an arrival process, a priority and an SLO target — and
:func:`materialize` turns it into one deterministic op stream: seeded
per-tenant arrival processes (deterministic / Poisson / bursty via
superposed on-off sources), per-tenant key streams drawn from the YCSB
mix generators over a shared preloaded population, interleaved in
simulated-time order.  The engines consume that stream through their
existing window machinery (each fill window becomes one
``RequestBatch``), so with admission disabled the open loop is
*byte-identical* to handing the same arrays to ``Simulator.run`` — the
parity gate in ``tests/test_traffic.py``.

:func:`serve` drives either engine (``Simulator`` or ``FleetEngine``)
from a spec: admission verdicts (:mod:`repro.serving.admission`) are a
deterministic pre-pass, the admitted stream runs through the engine, and
per-tenant ledgers (offered / shed / throttled / SLO violations,
goodput) land in the per-shard ``Stats`` so ``FleetStats`` aggregates
them like every other counter.  :func:`serve_grid` sweeps an
offered-load axis: scaling every tenant's rate by a common factor
compresses simulated time uniformly and preserves the interleave order,
so the admission-off curve amortizes ONE fleet structural replay across
the whole axis (``repro.core.fleet.traffic_curve``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.bench_kv.workloads import (load_keys, make_run_a, make_run_b,
                                      make_run_c, make_run_e)
from repro.core.stats import TenantLedger
from repro.core.types import OpKind

from .admission import ADMIT, SHED, THROTTLE, AdmissionConfig, admit

MIXES = ("load", "ycsb_a", "ycsb_b", "ycsb_c", "ycsb_e")
ARRIVALS = ("deterministic", "poisson", "bursty")


# ---------------------------------------------------------------- spec

@dataclass(frozen=True)
class TenantSpec:
    """One tenant: workload mix + offered rate + priority + SLO target.

    ``priority`` 0 is highest (shed last; below the admission floor it is
    never shed).  ``limit_ops_s`` arms a per-tenant token bucket
    (``burst_ops`` deep); ``None`` leaves the tenant unthrottled.  The
    bursty arrival process superposes ``n_sources`` on-off sources with
    exponential ON/OFF periods (means ``on_s`` / ``off_s``) emitting
    Poisson bursts while ON — heavier-tailed interarrivals than Poisson
    at the same mean rate (index-of-dispersion test in the traffic
    tests).
    """

    name: str
    rate_ops_s: float
    mix: str = "ycsb_a"              # one of MIXES
    arrival: str = "poisson"         # one of ARRIVALS
    priority: int = 1
    slo_ms: float = 50.0
    dist: str = "zipfian"            # key popularity over the population
    limit_ops_s: float | None = None
    burst_ops: float = 64.0
    n_sources: int = 4
    on_s: float = 0.2
    off_s: float = 0.8


@dataclass(frozen=True)
class TrafficSpec:
    """A reproducible multi-tenant open-loop scenario.

    ``population`` keys are preloaded (flood arrivals at
    ``load_rate_ops_s``), the store settles for ``settle_s``, then every
    tenant's stream runs for ``duration_s`` of simulated time.
    ``admission=None`` disables the controller (every op admitted) — the
    degenerate case the closed↔open parity gate pins.
    """

    tenants: tuple[TenantSpec, ...]
    duration_s: float
    seed: int = 7
    population: int = 20_000
    settle_s: float = 10.0
    load_rate_ops_s: float = 1e6
    admission: AdmissionConfig | None = None


@dataclass
class TrafficStream:
    """A materialized spec: the interleaved op stream plus provenance.

    ``tenant_ids[i]`` is the tenant index of op ``i`` (-1 for preload
    ops); ``tenant_seq[i]`` its position in that tenant's own generated
    sequence (the interleave-order invariant: per tenant, strictly
    increasing).  ``duration_s`` is the measured-phase simulated span
    (``spec.duration_s / load_factor``).
    """

    op_types: np.ndarray
    keys: np.ndarray
    arrivals: np.ndarray
    scan_lens: np.ndarray
    tenant_ids: np.ndarray
    tenant_seq: np.ndarray
    n_load: int
    t_run_start_s: float
    duration_s: float
    load_factor: float = 1.0

    @property
    def n_offered(self) -> int:
        """Offered traffic ops (preload excluded)."""
        return int(self.op_types.shape[0]) - self.n_load


# ---------------------------------------------------- arrival processes

def deterministic_arrivals(n: int, rate_ops_s: float) -> np.ndarray:
    """Fixed-interval offsets from 0: op i arrives at ``i / rate``."""
    return np.arange(n, dtype=np.float64) / rate_ops_s


def poisson_arrivals(n: int, rate_ops_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Poisson process offsets: i.i.d. exponential interarrivals."""
    return np.cumsum(rng.exponential(1.0 / rate_ops_s, size=n))


def bursty_arrivals(n: int, rate_ops_s: float, rng: np.random.Generator, *,
                    n_sources: int = 4, on_s: float = 0.2,
                    off_s: float = 0.8) -> np.ndarray:
    """Self-similar-ish offsets: superposed exponential on-off sources.

    Each source alternates OFF (mean ``off_s``) and ON (mean ``on_s``)
    periods and emits a Poisson burst while ON, at a rate chosen so the
    long-run aggregate matches ``rate_ops_s``.  The superposition's
    counting process is over-dispersed relative to Poisson (index of
    dispersion > 1) — the classic bursty-traffic construction.
    """
    duty = on_s / (on_s + off_s)
    src_rate_ops_s = rate_ops_s / (max(1, n_sources) * duty)
    chunks: list[np.ndarray] = []
    for quota in np.array_split(np.arange(n), max(1, n_sources)):
        need = int(quota.shape[0])
        got = 0
        t_s = rng.exponential(off_s)       # stagger: every source starts OFF
        while got < need:
            on = rng.exponential(on_s)
            k = min(int(rng.poisson(src_rate_ops_s * on)), need - got)
            if k:
                chunks.append(t_s + np.sort(rng.random(k)) * on)
                got += k
            t_s += on + rng.exponential(off_s)
    out = np.concatenate(chunks) if chunks else np.empty(0, np.float64)
    out.sort()
    return out


def _tenant_offsets(ten: TenantSpec, n: int,
                    rng: np.random.Generator) -> np.ndarray:
    if ten.arrival == "deterministic":
        return deterministic_arrivals(n, ten.rate_ops_s)
    if ten.arrival == "poisson":
        return poisson_arrivals(n, ten.rate_ops_s, rng)
    if ten.arrival == "bursty":
        return bursty_arrivals(n, ten.rate_ops_s, rng,
                               n_sources=ten.n_sources, on_s=ten.on_s,
                               off_s=ten.off_s)
    raise ValueError(f"unknown arrival process {ten.arrival!r} "
                     f"(one of {ARRIVALS})")


def _tenant_mix(ten: TenantSpec, population: np.ndarray, n: int, seed: int):
    """(op_types, keys, scan_lens) for one tenant's measured stream."""
    if ten.mix == "load":
        return (np.zeros(n, np.uint8), load_keys(n, seed),
                np.zeros(n, np.int32))
    makers = {"ycsb_a": make_run_a, "ycsb_b": make_run_b,
              "ycsb_c": make_run_c, "ycsb_e": make_run_e}
    if ten.mix not in makers:
        raise ValueError(f"unknown mix {ten.mix!r} (one of {MIXES})")
    spec = makers[ten.mix](population, n, dist=ten.dist, seed=seed)
    lens = spec.scan_lens if spec.scan_lens is not None \
        else np.zeros(n, np.int32)
    return spec.op_types, spec.keys, lens


# ------------------------------------------------------------ materialize

def materialize(spec: TrafficSpec,
                load_factor: float = 1.0) -> TrafficStream:
    """Deterministically expand a spec into one interleaved op stream.

    ``load_factor`` scales every tenant's offered rate by a common
    multiplier by compressing the measured phase's simulated time
    (op counts and the interleave order are invariant along the axis —
    what lets ``serve_grid`` amortize one structural replay across it).
    """
    pop = np.unique(load_keys(spec.population, spec.seed))
    n_load = int(pop.shape[0])
    load_arrivals = np.arange(n_load, dtype=np.float64) / spec.load_rate_ops_s
    t0 = (load_arrivals[-1] if n_load else 0.0) + spec.settle_s
    ops_l, keys_l, lens_l, arr_l, tid_l, seq_l = [], [], [], [], [], []
    for ti, ten in enumerate(spec.tenants):
        n_t = max(1, int(round(ten.rate_ops_s * spec.duration_s)))
        rng = np.random.default_rng((spec.seed, ti))
        offsets = _tenant_offsets(ten, n_t, rng)
        ot, ky, ln = _tenant_mix(ten, pop, n_t,
                                 seed=spec.seed + 101 * (ti + 1))
        ops_l.append(ot)
        keys_l.append(ky)
        lens_l.append(ln)
        arr_l.append(t0 + offsets / load_factor)
        tid_l.append(np.full(n_t, ti, np.int32))
        seq_l.append(np.arange(n_t, dtype=np.int64))
    op_types = np.concatenate([np.zeros(n_load, np.uint8)] + ops_l)
    keys = np.concatenate([pop] + keys_l)
    scan_lens = np.concatenate([np.zeros(n_load, np.int32)] + lens_l)
    arrivals = np.concatenate([load_arrivals] + arr_l)
    tenant_ids = np.concatenate([np.full(n_load, -1, np.int32)] + tid_l)
    tenant_seq = np.concatenate([np.zeros(n_load, np.int64)] + seq_l)
    # simulated-time interleave; stable ⇒ per-tenant order survives ties
    order = np.argsort(arrivals, kind="stable")
    return TrafficStream(
        op_types=op_types[order], keys=keys[order],
        arrivals=arrivals[order], scan_lens=scan_lens[order],
        tenant_ids=tenant_ids[order], tenant_seq=tenant_seq[order],
        n_load=n_load, t_run_start_s=t0,
        duration_s=spec.duration_s / load_factor, load_factor=load_factor)


# ----------------------------------------------------------------- serve

@dataclass
class ServeResult:
    """One open-loop run: engine result + admission + tenant accounting.

    ``latency_full`` aligns with ``stream`` (NaN where an op was shed or
    throttled — those ops never reached the engine); ``tenants`` holds
    one global :class:`~repro.core.stats.TenantLedger` per tenant (the
    per-shard splits live in the engine's ``Stats``).
    """

    res: object                      # SimResult of the admitted stream
    stream: TrafficStream
    verdicts: np.ndarray
    latency_full: np.ndarray
    tenants: list[TenantLedger]
    duration_s: float
    # executor phase-timing fragment for this run's bench row
    # (structural_s / temporal_s / lindley_s / finalize_s / cache_hit);
    # None when the caller didn't time the run
    timing: dict | None = None

    @property
    def offered_ops(self) -> int:
        return sum(t.ops_offered for t in self.tenants)

    @property
    def offered_ops_s(self) -> float:
        return self.offered_ops / max(self.duration_s, 1e-12)

    @property
    def goodput_ops_s(self) -> float:
        """Admitted ops that completed within their tenant's SLO, per
        second of measured simulated time."""
        good = sum(t.ops_admitted - t.slo_violations for t in self.tenants)
        return good / max(self.duration_s, 1e-12)

    @property
    def shed_frac(self) -> float:
        return sum(t.ops_shed for t in self.tenants) \
            / max(1, self.offered_ops)

    @property
    def throttled_frac(self) -> float:
        return sum(t.ops_throttled for t in self.tenants) \
            / max(1, self.offered_ops)

    @property
    def slo_violation_frac(self) -> float:
        adm = sum(t.ops_admitted for t in self.tenants)
        return sum(t.slo_violations for t in self.tenants) / max(1, adm)

    def tenant_latency(self, ti: int, op: int | None = None) -> np.ndarray:
        """Admitted-op latencies of tenant ``ti`` (optionally one kind)."""
        m = (self.stream.tenant_ids == ti) & (self.verdicts == ADMIT)
        if op is not None:
            m &= self.stream.op_types == op
        return self.latency_full[m]


def _ledger(ten: TenantSpec, mask: np.ndarray, verdicts: np.ndarray,
            latency_full: np.ndarray, slo_s: float) -> TenantLedger:
    v = verdicts[mask]
    lat = latency_full[mask][v == ADMIT]
    return TenantLedger(
        name=ten.name, priority=ten.priority, slo_ms=ten.slo_ms,
        ops_offered=int(mask.sum()),
        ops_admitted=int((v == ADMIT).sum()),
        ops_shed=int((v == SHED).sum()),
        ops_throttled=int((v == THROTTLE).sum()),
        slo_violations=int(np.count_nonzero(lat > slo_s)))


def _assemble(cfg, spec: TrafficSpec, stream: TrafficStream,
              verdicts: np.ndarray, shard_ids: np.ndarray, res,
              stats_sink=None, timing: dict | None = None) -> ServeResult:
    """Per-tenant accounting over one engine result.

    ``stats_sink`` (an engine, or None) receives the per-shard tenant
    ledger splits — None for grid passes, whose engine may live in the
    executor's structural cache rather than in the caller's hands.
    """
    n = int(stream.op_types.shape[0])
    latency_full = np.full(n, np.nan)
    latency_full[verdicts == ADMIT] = res.latency
    ledgers = []
    for ti, ten in enumerate(spec.tenants):
        slo_s = ten.slo_ms * 1e-3
        t_mask = stream.tenant_ids == ti
        ledgers.append(_ledger(ten, t_mask, verdicts, latency_full, slo_s))
        if stats_sink is not None:
            for s in range(stats_sink.n_shards):
                m = t_mask & (shard_ids == s)
                if not m.any():
                    continue
                led = _ledger(ten, m, verdicts, latency_full, slo_s)
                st = stats_sink.shard_stats[s]
                if ten.name in st.tenants:
                    st.tenants[ten.name].merge_from(led)
                else:
                    st.tenants[ten.name] = led
                st.ops_offered += led.ops_offered
                st.ops_shed += led.ops_shed
                st.ops_throttled += led.ops_throttled
                st.slo_violations += led.slo_violations
    if cfg.paranoid_checks:
        # conservation: every offered op got exactly one verdict
        for led in ledgers:
            assert led.ops_offered == (led.ops_admitted + led.ops_shed
                                       + led.ops_throttled), \
                f"tenant {led.name}: admission verdicts do not conserve " \
                f"offered ops ({led})"
        n_off = int((stream.tenant_ids >= 0).sum())
        assert sum(led.ops_offered for led in ledgers) == n_off, \
            "per-tenant offered counts do not cover the offered stream"
        assert int((verdicts[stream.tenant_ids < 0] != ADMIT).sum()) == 0, \
            "preload ops must bypass admission"
    return ServeResult(res=res, stream=stream, verdicts=verdicts,
                       latency_full=latency_full, tenants=ledgers,
                       duration_s=stream.duration_s, timing=timing)


def serve(sim, spec: TrafficSpec, *, load_factor: float = 1.0,
          record_stats: bool = True) -> ServeResult:
    """Drive an engine (``Simulator`` or ``FleetEngine``) from a spec.

    Admission (when configured) is a deterministic pre-pass over the
    offered stream, so both engines receive the same admitted stream and
    open-loop parity reduces to the existing engine parity.  With
    ``admission=None`` the engine sees the materialized arrays untouched
    — byte-identical to the closed-loop ``run`` on the same stream.
    """
    stream = materialize(spec, load_factor=load_factor)
    shard_ids = sim.router.shard_of(stream.keys)
    if spec.admission is None:
        verdicts = np.zeros(stream.op_types.shape[0], np.uint8)
        res = sim.run(stream.op_types, stream.keys, stream.arrivals,
                      stream.scan_lens)
    else:
        verdicts = admit(stream.op_types, stream.arrivals,
                         stream.tenant_ids, shard_ids, spec.tenants,
                         spec.admission, sim.cfg, sim.device)
        keep = verdicts == ADMIT
        res = sim.run(stream.op_types[keep], stream.keys[keep],
                      stream.arrivals[keep], stream.scan_lens[keep])
    return _assemble(sim.cfg, spec, stream, verdicts, shard_ids, res,
                     stats_sink=sim if record_stats else None)


def _admitted_point(task) -> ServeResult:
    """One admission-on grid point: a fresh namespace-built serial
    engine, timed end-to-end.  Module-level (fork-pool pickling
    contract); the serial engine has no phase split, so the whole run
    lands in ``structural_s`` and the pass phases report 0.0."""
    import time
    from repro.core.sim import Simulator
    from repro.core.uids import UidNamespace
    cfg, device, spec, factor = task
    t0 = time.perf_counter()
    sr = serve(Simulator(cfg, device, uids=UidNamespace()), spec,
               load_factor=factor)
    wall = time.perf_counter() - t0
    sr.timing = {"structural_s": round(wall, 6), "temporal_s": 0.0,
                 "lindley_s": 0.0, "finalize_s": 0.0, "cache_hit": False}
    return sr


def serve_grid(cfg, device, spec: TrafficSpec,
               load_factors: tuple[float, ...], *,
               backend: str = "numpy", workers: int = 1,
               cache=None) -> list[ServeResult]:
    """Sweep an offered-load axis: one :class:`ServeResult` per factor.

    Admission-off curves go through the sweep executor
    (:func:`repro.core.sweeps.run_point`): ONE structural replay — or a
    :class:`~repro.core.sweeps.StructuralCache` hit skipping it — then a
    cheap temporal pass per factor (the op stream is factor-invariant;
    only arrivals compress).  With admission on, each factor's admitted
    subset differs, so each point runs a fresh serial engine — those
    points are independent and dispatch over the executor's fork pool
    when ``workers > 1``.  Engines are namespace-built either way, so
    results are byte-identical at every worker count.  Grid passes keep
    per-pass tenant ledgers on the ``ServeResult`` only — single
    ``serve`` calls are the path that lands admission counters in
    ``Stats``.  Every result carries its phase-timing fragment in
    ``.timing``.
    """
    import time
    from repro.core.fleet import SweepPoint
    from repro.core.shard import ShardRouter
    from repro.core.sweeps import (LEDGER, PointTiming, parallel_map,
                                   run_point)
    t_grid = time.perf_counter()
    if spec.admission is not None:
        tasks = [(cfg, device, spec, f) for f in load_factors]
        out = parallel_map(_admitted_point, tasks, workers=workers)
        timings = [PointTiming(label=f"{cfg.policy}/adm/{f}",
                               cache_hit=False,
                               structural_s=sr.timing["structural_s"])
                   for f, sr in zip(load_factors, out)]
        LEDGER.add(wall_s=time.perf_counter() - t_grid, timings=timings)
        return out
    streams = [materialize(spec, load_factor=f) for f in load_factors]
    base = streams[0]
    point = SweepPoint(label=f"{cfg.policy}/off", cfg=cfg, device=device,
                       op_types=base.op_types, keys=base.keys,
                       scan_lens=base.scan_lens,
                       arrivals_grid=[s.arrivals for s in streams])
    results, timing = run_point(point, backend=backend, cache=cache)
    LEDGER.add(wall_s=time.perf_counter() - t_grid, timings=[timing])
    shard_ids = ShardRouter.from_config(cfg).shard_of(base.keys)
    verdicts = np.zeros(base.op_types.shape[0], np.uint8)
    return [_assemble(cfg, spec, stream, verdicts, shard_ids, res,
                      timing=timing.row(i))
            for i, (stream, res) in enumerate(zip(streams, results))]

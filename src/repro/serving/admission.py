"""Admission control in front of the per-shard foreground queues.

The controller is a *deterministic pre-pass* over the offered op stream:
per-tenant token buckets (a pure function of arrival times), a per-shard
queue-delay estimator (a Lindley recursion over nominal per-kind service
estimates, advanced only by admitted ops), and a leaky-bucket L0
write-pressure model (admitted write bytes fill estimated memtables;
estimated L0 SSTs drain at a rate derived from the device and the
config's growth factor).  Every offered op gets exactly one verdict —
ADMIT, THROTTLE (token bucket empty) or SHED (priority-aware overload
protection) — so ``shed + throttled + admitted == offered`` by
construction, and the serving layer re-asserts it at runtime under
``cfg.paranoid_checks``.

Design note: shedding off *live* engine state (actual queue delay, actual
L0 depth) would make the admitted stream a function of simulated timing,
which breaks the fleet engine's arrival-independent structural replay and
with it the serial==fleet parity gate.  The estimator trades exactness
for that property: both engines receive the *same* admitted stream, so
open-loop parity is inherited from the existing engine parity.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.sim import GET_CPU, PUT_SERVICE, SCAN_CPU
from repro.core.types import DeviceModel, LSMConfig, OpKind

# verdict codes (uint8): one per offered op, never silently dropped
ADMIT = 0
THROTTLE = 1
SHED = 2


class TokenBucket:
    """Classic token bucket over *simulated* arrival times.

    Capacity ``burst_ops`` tokens, refilled continuously at
    ``rate_ops_s``; an op is admitted iff a whole token is available at
    its arrival instant.  Over any window ``[t1, t2]`` the bucket admits
    at most ``burst_ops + rate_ops_s * (t2 - t1)`` ops — the property the
    traffic tests pin.  ``rate_ops_s <= 0`` disables the limit.
    """

    __slots__ = ("rate_ops_s", "burst_ops", "tokens", "t_last_s")

    def __init__(self, rate_ops_s: float, burst_ops: float = 64.0):
        self.rate_ops_s = float(rate_ops_s)
        self.burst_ops = float(max(1.0, burst_ops))
        self.tokens = self.burst_ops
        self.t_last_s = 0.0

    def try_admit(self, t_s: float) -> bool:
        """Refill to ``t_s`` and consume one token if available."""
        if self.rate_ops_s <= 0.0:
            return True
        if t_s > self.t_last_s:
            self.tokens = min(self.burst_ops,
                              self.tokens
                              + (t_s - self.t_last_s) * self.rate_ops_s)
            self.t_last_s = t_s
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclass(frozen=True)
class AdmissionConfig:
    """Knobs of the admission pre-pass (see module docstring).

    ``max_queue_delay_s`` is the shed threshold for priority
    ``shed_priority_floor``; each further priority level divides it by
    ``priority_factor`` (lower priority ⇒ shed earlier).  Priorities
    *below* the floor (0 = highest) are never shed — only throttled by
    their own token bucket, if any.
    """

    max_queue_delay_s: float = 0.10     # shed threshold at the floor priority
    priority_factor: float = 4.0        # threshold divisor per priority level
    shed_priority_floor: int = 1        # priorities < floor are never shed
    l0_shed_depth: float = 6.0          # estimated L0 SSTs that shed writes
    l0_drain_factor: float = 4.0        # est. L0 drain time, in sst-I/O units
    nominal_get_blocks: float = 2.0     # controller's GET device-read model
    nominal_scan_blocks: float = 8.0    # controller's SCAN device-read model


def nominal_service_s(op_types: np.ndarray, acfg: AdmissionConfig,
                      device: DeviceModel) -> np.ndarray:
    """Controller-side per-op service estimate (seconds).

    Deliberately the *nominal* cost — CPU plus the modeled device reads —
    with no busy inflation or stall feedback: it only has to rank load
    against capacity, not reproduce the DES.
    """
    block_read_s = device.read_time(device.block_size)
    per_kind = np.zeros(4, np.float64)
    per_kind[int(OpKind.PUT)] = PUT_SERVICE
    per_kind[int(OpKind.DELETE)] = PUT_SERVICE
    per_kind[int(OpKind.GET)] = GET_CPU + acfg.nominal_get_blocks * block_read_s
    per_kind[int(OpKind.SCAN)] = (SCAN_CPU
                                  + acfg.nominal_scan_blocks * block_read_s)
    return per_kind[op_types]


def admit(op_types: np.ndarray, arrivals: np.ndarray,
          tenant_ids: np.ndarray, shard_ids: np.ndarray,
          tenants, acfg: AdmissionConfig, cfg: LSMConfig,
          device: DeviceModel) -> np.ndarray:
    """One verdict per op (ADMIT / THROTTLE / SHED), arrival order.

    Ops with ``tenant_ids < 0`` (store preload) bypass admission and do
    not advance the estimators: they model the store's population, not
    offered traffic.  ``tenants`` is the spec sequence indexed by
    ``tenant_ids`` (needs ``priority`` / ``limit_ops_s`` / ``burst_ops``).
    """
    n = int(arrivals.shape[0])
    verdicts = np.zeros(n, np.uint8)
    svc = nominal_service_s(op_types, acfg, device)
    is_write = (op_types == OpKind.PUT) | (op_types == OpKind.DELETE)
    buckets = [TokenBucket(t.limit_ops_s or 0.0, t.burst_ops)
               for t in tenants]
    sheddable = [t.priority >= acfg.shed_priority_floor for t in tenants]
    threshold_s = [acfg.max_queue_delay_s
                   / acfg.priority_factor
                   ** max(0, t.priority - acfg.shed_priority_floor)
                   for t in tenants]
    # estimated L0 drain: one relief compaction touches the sst plus the
    # overlap the growth factor implies — l0_drain_factor sst-I/O units
    sst_io_s = (device.read_time(cfg.sst_size)
                + device.write_time(cfg.sst_size))
    l0_drain_s = acfg.l0_drain_factor * sst_io_s
    n_shards = max(1, cfg.n_shards)
    depart_est_s = [0.0] * n_shards      # Lindley clock per shard queue
    l0_est = [0.0] * n_shards            # estimated L0 SSTs (leaky)
    l0_t_s = [0.0] * n_shards
    fill_bytes = [0.0] * n_shards        # admitted write bytes mod memtable
    for i in range(n):
        ti = int(tenant_ids[i])
        if ti < 0:
            continue                     # preload: always admitted
        t = float(arrivals[i])
        s = int(shard_ids[i])
        if not buckets[ti].try_admit(t):
            verdicts[i] = THROTTLE
            continue
        l0_est[s] = max(0.0, l0_est[s] - (t - l0_t_s[s]) / l0_drain_s)
        l0_t_s[s] = t
        if sheddable[ti]:
            delay_est_s = max(0.0, depart_est_s[s] - t)
            if delay_est_s > threshold_s[ti] or (
                    is_write[i] and l0_est[s] >= acfg.l0_shed_depth):
                verdicts[i] = SHED
                continue
        depart_est_s[s] = max(depart_est_s[s], t) + svc[i]
        if is_write[i]:
            fill_bytes[s] += cfg.kv_size
            if fill_bytes[s] >= cfg.memtable_size:
                fill_bytes[s] -= cfg.memtable_size
                l0_est[s] += 1.0
    return verdicts

"""Paged KV-cache pool for serving.

Pages are fixed-size token blocks ([PS, Hkv, Dh] per layer); sequences own
page lists via the page table.  The pool integrates with
``kernels/paged_attention`` (scalar-prefetch gather on TPU) and with the
LSM-backed prefix cache (prefix_cache.py) which pins shared pages.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np


@dataclass
class PagePool:
    n_pages: int
    page_size: int
    n_layers: int
    n_kv_heads: int
    head_dim: int
    dtype: str = "float32"
    k_pages: jnp.ndarray = field(init=False)   # [L, NP, PS, Hkv, Dh]
    v_pages: jnp.ndarray = field(init=False)

    def __post_init__(self):
        shape = (self.n_layers, self.n_pages, self.page_size,
                 self.n_kv_heads, self.head_dim)
        self.k_pages = jnp.zeros(shape, jnp.dtype(self.dtype))
        self.v_pages = jnp.zeros(shape, jnp.dtype(self.dtype))
        self._free = list(range(self.n_pages - 1, -1, -1))
        self.refcount = np.zeros(self.n_pages, np.int32)

    # ------------------------------------------------------------- alloc
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError("page pool exhausted")
        p = self._free.pop()
        self.refcount[p] = 1
        return p

    def pin(self, page: int) -> None:
        self.refcount[page] += 1

    def release(self, page: int) -> None:
        self.refcount[page] -= 1
        if self.refcount[page] <= 0:
            self.refcount[page] = 0
            self._free.append(page)

    # ------------------------------------------------------------- write
    def write_tokens(self, layer: int, page: int, offset: int,
                     k: jnp.ndarray, v: jnp.ndarray) -> None:
        """k, v: [T, Hkv, Dh] with offset+T <= page_size."""
        self.k_pages = self.k_pages.at[layer, page, offset:offset + k.shape[0]].set(k)
        self.v_pages = self.v_pages.at[layer, page, offset:offset + v.shape[0]].set(v)


@dataclass
class Sequence:
    seq_id: int
    tokens: list[int] = field(default_factory=list)
    pages: list[int] = field(default_factory=list)
    length: int = 0
    shared_prefix_len: int = 0

    def pages_needed(self, page_size: int, new_tokens: int) -> int:
        have = len(self.pages) * page_size
        need = self.length + new_tokens
        return max(0, -(-(need - have) // page_size))

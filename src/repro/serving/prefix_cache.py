"""LSM-backed prefix cache — the paper's KV store serving the serving stack.

Shared prompt prefixes (system prompts, few-shot preambles, RAG headers)
map token-block hashes to pinned KV pages.  The index is a real
:class:`repro.core.LSMTree` with the **vLSM policy**: under heavy insert
churn (every new prompt inserts its block chain) a tiered-L0 index stalls
exactly like RocksDB does in the paper's Fig. 1 — vLSM's narrow chains
keep p99 insert latency flat, which benchmarks/serving_tail.py measures by
driving both policies with the DES.

Design: key = rolling blake2 hash of the token prefix at each block
boundary; the LSM's seqno doubles as the handle into ``pages`` (seq →
page list entry).  Lookup walks block boundaries longest-first; eviction
releases pages of entries whose key was superseded or dropped.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from repro.core import LSMConfig, LSMTree

from .kv_cache import PagePool


def _hash_tokens(tokens) -> int:
    h = hashlib.blake2b(np.asarray(tokens, np.int32).tobytes(),
                        digest_size=8).digest()
    return int.from_bytes(h, "little") & 0x7FFF_FFFF_FFFF_FFFF


@dataclass
class PrefixEntry:
    pages: list[int]
    n_tokens: int
    hits: int = 0


class PrefixCache:
    def __init__(self, pool: PagePool, block_tokens: int = 128,
                 lsm_cfg: LSMConfig | None = None):
        self.pool = pool
        self.block = block_tokens
        self.index = LSMTree(lsm_cfg or LSMConfig.vlsm_default(scale=1 << 18)
                             .with_(kv_size=64))
        self.entries: dict[int, PrefixEntry] = {}    # seq -> entry
        self.latest: dict[int, int] = {}             # key -> seq (fast map)

    # ----------------------------------------------------------- internal
    def _put(self, key: int) -> int:
        t = self.index
        if t.memtable.room < 1:
            t.seal_memtable()
            t.flush_immutable()
            t.background_triggers()
            t.drain_jobs()
        seq = int(t.put_batch(np.asarray([key], np.int64))[0])
        self.latest[key] = seq
        return seq

    # -------------------------------------------------------------- insert
    def insert(self, tokens, pages_by_block: list[list[int]]) -> int:
        """Register prefix blocks of ``tokens``; pages get pinned.
        ``pages_by_block[i]`` are the pool pages holding block i."""
        n_blocks = min(len(tokens) // self.block, len(pages_by_block))
        inserted = 0
        for i in range(n_blocks):
            key = _hash_tokens(tokens[:(i + 1) * self.block])
            if key in self.latest:
                continue
            seq = self._put(key)
            for p in pages_by_block[i]:
                self.pool.pin(p)
            self.entries[seq] = PrefixEntry(
                pages=list(pages_by_block[i]),
                n_tokens=(i + 1) * self.block)
            inserted += 1
        return inserted

    # -------------------------------------------------------------- lookup
    def match(self, tokens) -> tuple[int, list[int]]:
        """Longest cached prefix of ``tokens``: (n_tokens, pages)."""
        n_blocks = len(tokens) // self.block
        for i in range(n_blocks, 0, -1):
            key = _hash_tokens(tokens[:i * self.block])
            seq, _reads, _probed = self.index.get(int(key))
            if seq is not None and seq in self.entries:
                entry = self.entries[seq]
                entry.hits += 1
                pages: list[int] = []
                # assemble the chain of blocks 1..i
                for j in range(1, i + 1):
                    kj = _hash_tokens(tokens[:j * self.block])
                    sj = self.latest.get(kj)
                    if sj is None or sj not in self.entries:
                        break
                    pages.extend(self.entries[sj].pages)
                else:
                    return i * self.block, pages
        return 0, []

    # -------------------------------------------------------------- evict
    def evict_lru(self, n_entries: int = 1) -> int:
        """Release the least-hit entries' pages (capacity pressure)."""
        victims = sorted(self.entries.items(),
                         key=lambda kv: (kv[1].hits, kv[0]))[:n_entries]
        for seq, entry in victims:
            for p in entry.pages:
                self.pool.release(p)
            del self.entries[seq]
            dead = [k for k, s in self.latest.items() if s == seq]
            for k in dead:
                del self.latest[k]
        return len(victims)

    def stats(self) -> dict:
        return {"entries": len(self.entries),
                "index": self.index.stats.summary(),
                "free_pages": self.pool.free_pages}

"""Serving layer: LLM-serving scaffolding (paged KV pool, prefix cache)
plus the open-loop traffic/admission layer over the LSM engines.

``traffic`` materializes multi-tenant :class:`TrafficSpec` scenarios
into simulated-time-ordered op streams and drives either engine;
``admission`` is the deterministic pre-pass controller (token buckets,
priority-aware shedding) in front of each shard's foreground queue.
"""

from .admission import (ADMIT, SHED, THROTTLE, AdmissionConfig,
                        TokenBucket, admit)
from .kv_cache import PagePool, Sequence
from .prefix_cache import PrefixCache
from .traffic import (ServeResult, TenantSpec, TrafficSpec, TrafficStream,
                      bursty_arrivals, deterministic_arrivals, materialize,
                      poisson_arrivals, serve, serve_grid)

__all__ = [
    "ADMIT", "AdmissionConfig", "PagePool", "PrefixCache", "SHED",
    "THROTTLE", "ServeResult", "Sequence", "TenantSpec", "TokenBucket",
    "TrafficSpec", "TrafficStream", "admit", "bursty_arrivals",
    "deterministic_arrivals", "materialize", "poisson_arrivals", "serve",
    "serve_grid",
]

from .kv_cache import PagePool, Sequence
from .prefix_cache import PrefixCache

__all__ = ["PagePool", "PrefixCache", "Sequence"]

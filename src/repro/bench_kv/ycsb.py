"""Open-loop YCSB harness (§5 of the paper, Figure 5).

Requests are generated at a fixed rate into an unbounded queue — the
coordinated-omission-free methodology — and the DES measures end-to-end
per-request latency from the issue timestamp.  ``sustainable_throughput``
mirrors the paper's profiling run: drive the store at a high rate and
report the completion rate, then measure tails at fractions of it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import DeviceModel, LSMConfig, SimResult, Simulator
from repro.core.stats import (CYC_MANIFEST_FLUSH, CYC_MERGE_KEY, CYC_OP_BASE,
                              CYC_OVERLAP_PROBE, CYC_SST_CREATE)

from .workloads import WorkloadSpec

PAPER_SCALE = 64 << 20   # the byte size that "64 MB" maps to at scale 1.0


@dataclass
class YCSBResult:
    name: str
    sim: SimResult
    rate: float
    scale_lam: float
    extra: dict = field(default_factory=dict)

    def cycles_per_op(self) -> float:
        """Scale-invariant CPU proxy: per-file overheads are charged at the
        λ-scaled rate so file counts per op match the paper's at the same
        *relative* SST size."""
        st = self.sim.stats
        lam = self.scale_lam
        cyc = (CYC_MERGE_KEY * st.merged_keys
               + CYC_OVERLAP_PROBE * st.overlap_probes
               + CYC_SST_CREATE * lam * st.ssts_created
               + CYC_MANIFEST_FLUSH * lam * st.manifest_flushes
               + CYC_OP_BASE * st.ops)
        return cyc / max(1, st.ops)

    def row(self) -> dict:
        d = {"workload": self.name, "rate_ops_s": int(self.rate)}
        d.update(self.sim.summary())
        d["cycles_per_op_scaled"] = round(self.cycles_per_op(), 0)
        d.update(self.extra)
        return d


def run_ycsb(cfg: LSMConfig, spec: WorkloadSpec, rate: float,
             n_regions: int = 1, scale: int | None = None,
             device: DeviceModel | None = None,
             preload: np.ndarray | None = None) -> YCSBResult:
    """Run one workload at a fixed request rate against a fresh store.

    ``preload`` keys are ingested first (back-to-back at the same rate) so
    mixed Run-X workloads hit a populated store, as YCSB does.
    """
    scale = scale if scale is not None else cfg.memtable_size
    lam = scale / PAPER_SCALE
    device = device or DeviceModel.scaled(lam)
    sim = Simulator(cfg, device, n_regions=n_regions)

    op_types, keys = spec.op_types, spec.keys
    scan_lens = spec.scan_lens
    n_pre = 0
    if preload is not None and preload.size:
        n_pre = preload.shape[0]
        op_types = np.concatenate([np.zeros(n_pre, np.uint8), op_types])
        keys = np.concatenate([preload, keys])
        if scan_lens is not None:
            scan_lens = np.concatenate([np.zeros(n_pre, np.int32), scan_lens])
    arrivals = np.arange(op_types.shape[0], dtype=np.float64) / rate
    res = sim.run(op_types, keys, arrivals, scan_lens=scan_lens)
    if n_pre:
        # report latency/percentiles on the measured phase only
        res = SimResult(
            arrivals=res.arrivals[n_pre:], latency=res.latency[n_pre:],
            op_types=res.op_types[n_pre:], stall_total=res.stall_total,
            stall_max=res.stall_max, n_stalls=res.n_stalls, stats=res.stats,
            job_log=res.job_log, makespan=res.makespan,
            get_reads=res.get_reads[n_pre:], get_probed=res.get_probed[n_pre:],
        )
    out = YCSBResult(spec.name, res, rate, lam)
    out.extra["levels_mb"] = [round(s / 1e6, 2) for s in sim.trees[0].level_sizes()]
    out.extra["_sim"] = sim
    return out


def sustainable_throughput(cfg: LSMConfig, spec: WorkloadSpec,
                           n_regions: int = 1, scale: int | None = None,
                           probe_rate: float = 1.5e6) -> float:
    """Paper §5: profile at a very high generator rate; the completion rate
    is the system's sustainable throughput."""
    res = run_ycsb(cfg, spec, probe_rate, n_regions, scale)
    return res.sim.throughput

"""YCSB / db_bench workload generators (key streams + op mixes).

The paper's methodology (§5): YCSB Load A (100% insert) for write tails,
Run A (50r/50u), Run B (95r/5u), Run C (100r), Run D (95 read-latest /
5 insert), Run E (95 scan / 5 insert — the range-query workload); uniform
and Zipfian(0.99) request distributions; db_bench-style fillrandom with
uniform and Pareto key popularity (Meta's production mix).

Op streams are typed (:class:`repro.core.OpKind`): 0 PUT, 1 GET, 2 DELETE,
3 SCAN; SCAN ops carry a per-op requested key count in ``scan_lens``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import OpKind

KEYSPACE = 1 << 48


@dataclass
class WorkloadSpec:
    name: str
    op_types: np.ndarray       # OpKind values
    keys: np.ndarray
    scan_lens: np.ndarray | None = None   # per-op SCAN key count (None: no scans)


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def load_keys(n: int, seed: int = 7) -> np.ndarray:
    """Distinct-ish uniform keys for the load phase."""
    return _rng(seed).integers(0, KEYSPACE, size=n, dtype=np.int64)


def _zipf_rank_sample(m: int, n: int, theta: float, seed: int) -> np.ndarray:
    """Sample ``n`` ranks in [0, m) with probability ∝ 1/(rank+1)^theta
    via inverse-CDF over the (normalized) generalized harmonic cumsum —
    exact, vectorized.  Shared by both zipf key mappers."""
    ranks = np.arange(1, m + 1, dtype=np.float64)
    w = 1.0 / ranks ** theta
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = _rng(seed).random(n)
    return np.searchsorted(cdf, u, side="left")


def zipf_keys(population: np.ndarray, n: int, theta: float = 0.99,
              seed: int = 11) -> np.ndarray:
    """YCSB-style Zipfian sampling over an item population."""
    m = population.shape[0]
    idx = _zipf_rank_sample(m, n, theta, seed)
    # YCSB scatters the hot ranks across the keyspace via a hash; shuffling
    # the population achieves the same decorrelation.
    perm = _rng(seed + 1).permutation(m)
    return population[perm[idx]]


def zipf_ranked_keys(population: np.ndarray, n: int, theta: float = 0.99,
                     seed: int = 11) -> np.ndarray:
    """Zipfian sampling WITHOUT YCSB's scatter permutation: rank *r* maps
    to the r-th **smallest** key, so popularity decays along the key
    order.  This is the hot-range request pattern — and, over a
    range-partitioned keyspace, the canonical *hot-shard* scenario: the
    shard owning the head of the key order absorbs most of the traffic
    while its neighbours idle (``db_bench``'s ``shard_sweep`` hot-shard
    rows drive exactly this against the ``range`` router)."""
    idx = _zipf_rank_sample(population.shape[0], n, theta, seed)
    return np.sort(population)[idx]


def pareto_keys(population: np.ndarray, n: int, alpha: float = 1.16,
                seed: int = 13) -> np.ndarray:
    """Pareto popularity (db_bench's Meta-production-like distribution).

    Rank *i* gets the exact probability mass of the Pareto (Lomax) density
    on [i, i+1) — ``w_i = (1+i)^-alpha - (2+i)^-alpha`` — sampled by
    inverse-CDF over the normalized cumsum, mirroring :func:`zipf_keys`.
    A rank's popularity is a fixed function of (rank, alpha, m): unlike
    the old ``raw / raw.max()`` normalization, it does not depend on the
    sample size ``n`` (the max of ``n`` Pareto draws grows with ``n``, so
    the old mapping reshuffled popularity whenever ``n`` changed).
    """
    m = population.shape[0]
    edges = np.arange(m + 1, dtype=np.float64)
    cdf = np.cumsum((1.0 + edges[:-1]) ** -alpha - (1.0 + edges[1:]) ** -alpha)
    cdf /= cdf[-1]
    u = _rng(seed).random(n)
    idx = np.searchsorted(cdf, u, side="left")
    perm = _rng(seed + 1).permutation(m)
    return population[perm[idx]]


def make_load_a(n: int, seed: int = 7) -> WorkloadSpec:
    return WorkloadSpec("load_a", np.zeros(n, np.uint8), load_keys(n, seed))


def _mixed(name: str, population: np.ndarray, n: int, read_frac: float,
           dist: str, seed: int) -> WorkloadSpec:
    r = _rng(seed)
    op_types = (r.random(n) < read_frac).astype(np.uint8)  # 1 = read
    if dist == "zipfian":
        keys = zipf_keys(population, n, seed=seed + 2)
    elif dist == "zipf_ranked":
        keys = zipf_ranked_keys(population, n, seed=seed + 2)
    elif dist == "pareto":
        keys = pareto_keys(population, n, seed=seed + 2)
    else:
        keys = population[r.integers(0, population.shape[0], size=n)]
    return WorkloadSpec(name, op_types, keys)


def make_run_a(population: np.ndarray, n: int, dist: str = "uniform",
               seed: int = 21) -> WorkloadSpec:
    return _mixed("run_a", population, n, 0.5, dist, seed)


def make_run_b(population: np.ndarray, n: int, dist: str = "uniform",
               seed: int = 23) -> WorkloadSpec:
    return _mixed("run_b", population, n, 0.95, dist, seed)


def make_run_c(population: np.ndarray, n: int, dist: str = "uniform",
               seed: int = 25) -> WorkloadSpec:
    return _mixed("run_c", population, n, 1.0, dist, seed)


def make_run_e(population: np.ndarray, n: int, dist: str = "zipfian",
               seed: int = 29, max_scan_len: int = 100) -> WorkloadSpec:
    """YCSB-E: 95% SCAN / 5% insert.  Scan start keys follow the request
    distribution; scan lengths are uniform in [1, max_scan_len] (the YCSB
    default).  Inserts add fresh keys, as YCSB-E's INSERT phase does."""
    r = _rng(seed)
    op_types = np.where(r.random(n) < 0.95, np.uint8(OpKind.SCAN),
                        np.uint8(OpKind.PUT))
    keys = np.empty(n, np.int64)
    inserts = np.nonzero(op_types == OpKind.PUT)[0]
    keys[inserts] = load_keys(inserts.shape[0], seed + 1)
    scans = np.nonzero(op_types == OpKind.SCAN)[0]
    if dist == "zipfian":
        starts = zipf_keys(population, scans.shape[0], seed=seed + 2)
    elif dist == "pareto":
        starts = pareto_keys(population, scans.shape[0], seed=seed + 2)
    else:
        starts = population[r.integers(0, population.shape[0],
                                       size=scans.shape[0])]
    keys[scans] = starts
    scan_lens = np.zeros(n, np.int32)
    scan_lens[scans] = r.integers(1, max_scan_len + 1, size=scans.shape[0])
    return WorkloadSpec("run_e", op_types, keys, scan_lens)


def make_run_d(population: np.ndarray, n: int, seed: int = 27) -> WorkloadSpec:
    """95% read-latest / 5% insert."""
    r = _rng(seed)
    op_types = (r.random(n) < 0.95).astype(np.uint8)
    keys = np.empty(n, np.int64)
    inserts = np.nonzero(op_types == 0)[0]
    keys[inserts] = load_keys(inserts.shape[0], seed + 1)
    # read-latest: sample recent inserts with geometric recency bias
    reads = np.nonzero(op_types == 1)[0]
    pool = np.concatenate([population, keys[inserts]])
    lag = r.geometric(p=0.01, size=reads.shape[0])
    idx = np.maximum(pool.shape[0] - lag, 0)
    keys[reads] = pool[idx]
    return WorkloadSpec("run_d", op_types, keys)

"""YCSB / db_bench workload generators (key streams + op mixes).

The paper's methodology (§5): YCSB Load A (100% insert) for write tails,
Run A (50r/50u), Run B (95r/5u), Run C (100r), Run D (95 read-latest /
5 insert); uniform and Zipfian(0.99) request distributions; db_bench-style
fillrandom with uniform and Pareto key popularity (Meta's production mix).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

KEYSPACE = 1 << 48


@dataclass
class WorkloadSpec:
    name: str
    op_types: np.ndarray       # 0 = put, 1 = get
    keys: np.ndarray


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def load_keys(n: int, seed: int = 7) -> np.ndarray:
    """Distinct-ish uniform keys for the load phase."""
    return _rng(seed).integers(0, KEYSPACE, size=n, dtype=np.int64)


def zipf_keys(population: np.ndarray, n: int, theta: float = 0.99,
              seed: int = 11) -> np.ndarray:
    """YCSB-style Zipfian sampling over an item population.

    Ranks are sampled with probability ∝ 1/rank^theta via inverse-CDF over
    the (normalized) generalized harmonic cumsum — exact, vectorized.
    """
    m = population.shape[0]
    ranks = np.arange(1, m + 1, dtype=np.float64)
    w = 1.0 / ranks ** theta
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = _rng(seed).random(n)
    idx = np.searchsorted(cdf, u, side="left")
    # YCSB scatters the hot ranks across the keyspace via a hash; shuffling
    # the population achieves the same decorrelation.
    perm = _rng(seed + 1).permutation(m)
    return population[perm[idx]]


def pareto_keys(population: np.ndarray, n: int, alpha: float = 1.16,
                seed: int = 13) -> np.ndarray:
    """Pareto popularity (db_bench's Meta-production-like distribution)."""
    m = population.shape[0]
    r = _rng(seed)
    raw = r.pareto(alpha, size=n)
    idx = np.minimum((raw / (raw.max() + 1e-9) * m).astype(np.int64), m - 1)
    perm = _rng(seed + 1).permutation(m)
    return population[perm[idx]]


def make_load_a(n: int, seed: int = 7) -> WorkloadSpec:
    return WorkloadSpec("load_a", np.zeros(n, np.uint8), load_keys(n, seed))


def _mixed(name: str, population: np.ndarray, n: int, read_frac: float,
           dist: str, seed: int) -> WorkloadSpec:
    r = _rng(seed)
    op_types = (r.random(n) < read_frac).astype(np.uint8)  # 1 = read
    if dist == "zipfian":
        keys = zipf_keys(population, n, seed=seed + 2)
    elif dist == "pareto":
        keys = pareto_keys(population, n, seed=seed + 2)
    else:
        keys = population[r.integers(0, population.shape[0], size=n)]
    return WorkloadSpec(name, op_types, keys)


def make_run_a(population: np.ndarray, n: int, dist: str = "uniform",
               seed: int = 21) -> WorkloadSpec:
    return _mixed("run_a", population, n, 0.5, dist, seed)


def make_run_b(population: np.ndarray, n: int, dist: str = "uniform",
               seed: int = 23) -> WorkloadSpec:
    return _mixed("run_b", population, n, 0.95, dist, seed)


def make_run_c(population: np.ndarray, n: int, dist: str = "uniform",
               seed: int = 25) -> WorkloadSpec:
    return _mixed("run_c", population, n, 1.0, dist, seed)


def make_run_d(population: np.ndarray, n: int, seed: int = 27) -> WorkloadSpec:
    """95% read-latest / 5% insert."""
    r = _rng(seed)
    op_types = (r.random(n) < 0.95).astype(np.uint8)
    keys = np.empty(n, np.int64)
    inserts = np.nonzero(op_types == 0)[0]
    keys[inserts] = load_keys(inserts.shape[0], seed + 1)
    # read-latest: sample recent inserts with geometric recency bias
    reads = np.nonzero(op_types == 1)[0]
    pool = np.concatenate([population, keys[inserts]])
    lag = r.geometric(p=0.01, size=reads.shape[0])
    idx = np.maximum(pool.shape[0] - lag, 0)
    keys[reads] = pool[idx]
    return WorkloadSpec("run_d", op_types, keys)

"""db_bench-style driver (paper §5: Meta-datacenter population runs).

``fillrandom`` populates the store to a target level-fill (the paper fills
all levels but the last) under uniform or Pareto key popularity and
reports I/O amplification — the paper measures only amplification with
db_bench, as do we.  ``read_path`` is the read-side companion: a
read-heavy YCSB-C run that times the DES wall-clock end-to-end, tracking
the batched LevelIndex GET path.

Results are persisted as machine-readable JSON rows (policy, io_amp,
p99s, sim wall-clock) so the perf trajectory is diffable across commits:

    PYTHONPATH=src python -m repro.bench_kv.db_bench --json BENCH_dbbench.json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import DeviceModel, LSMConfig, OpKind, Simulator
from repro.core import level_index

from .workloads import load_keys, make_run_c, make_run_e, pareto_keys


def fillrandom(cfg: LSMConfig, n_ops: int, *, dist: str = "uniform",
               scale: int | None = None, seed: int = 7) -> dict:
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    base = load_keys(n_ops, seed)
    keys = base if dist == "uniform" else pareto_keys(base, n_ops, seed=seed)
    arrivals = np.arange(n_ops) / 1e6          # flood: amp-only measurement
    t0 = time.perf_counter()
    res = sim.run(np.zeros(n_ops, np.uint8), keys, arrivals)
    wall = time.perf_counter() - t0
    st = res.stats
    return {
        "bench": "fillrandom", "dist": dist, "policy": cfg.policy.value,
        "ops": n_ops,
        "io_amp": round(st.io_amp, 2), "write_amp": round(st.write_amp, 2),
        "levels_filled": sum(1 for s in sim.trees[0].level_sizes() if s > 0),
        "compactions": sum(st.compactions_per_level.values()),
        "wall_clock_s": round(wall, 3),
    }


def read_path(cfg: LSMConfig, n_ops: int = 200_000, n_pop: int = 100_000, *,
              scale: int | None = None, rate: float = 1e4,
              seed: int = 7) -> dict:
    """Read-heavy YCSB-C probe (zipfian GETs over a preloaded store): the
    wall-clock of the whole DES run is the tracked quantity — it is
    dominated by the GET path, one ``LSMTree.get_batch`` per window."""
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_c(pop, n_ops, dist="zipfian")
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    arrivals = np.arange(op_types.shape[0], dtype=np.float64) / rate
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    t0 = time.perf_counter()
    res = sim.run(op_types, keys, arrivals)
    wall = time.perf_counter() - t0
    g = res.op_types == 1
    return {
        "bench": "read_path", "workload": "run_c",
        "policy": cfg.policy.value, "ops": n_ops,
        "wall_clock_s": round(wall, 3),
        "p99_get_ms": round(res.pct(99, op=1) * 1e3, 3),
        "device_reads": int(sim.stats.device_reads),
        "mean_ssts_probed": round(float(res.get_probed[g].mean()), 3),
        "index_backend": cfg.index_backend or level_index.get_backend(),
    }


def seekrandom(cfg: LSMConfig, n_ops: int = 40_000, n_pop: int = 60_000, *,
               scale: int | None = None, rate: float = 300.0,
               write_rate: float = 800.0, settle_s: float = 30.0,
               seed: int = 7) -> dict:
    """Scan-tail probe: YCSB-E SCANs measured while a writer streams —
    db_bench's ``seekrandomwhilewriting`` counterpart.

    Methodology: load-phase flood, a ``settle_s`` compaction settle
    (YCSB's wait between load and run), then the measured run: the YCSB-E
    mix (95% zipfian SCANs / 5% inserts) arrives at ``rate`` while a
    background writer streams fresh keys at the same fixed ``write_rate``
    for every policy (db_bench's ``--benchmark_write_rate_limit``
    convention; the default sits inside every policy's sustainable region
    at the benchmark scale).  The scan tail then captures how each
    policy's compaction behaviour — chain width, write stalls, device
    busy time — bleeds into foreground range queries: the paper's
    read-tail mechanism (P99 reads up to 12.5x), extended to scans.
    vLSM's narrow chains keep its scan P99 low while its continuous small
    compactions cost a little median; RocksDB's wide tiering merges stall
    the queue and blow up the tail."""
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    w_rate = write_rate
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_e(pop, n_ops, dist="zipfian", seed=seed + 3)
    load_arrivals = np.arange(pop.shape[0], dtype=np.float64) / 1e6
    t_run = load_arrivals[-1] + settle_s
    run_arrivals = t_run + np.arange(n_ops, dtype=np.float64) / rate
    n_wr = int(n_ops / rate * w_rate)
    writer_keys = load_keys(n_wr, seed + 9)
    writer_arrivals = t_run + np.arange(n_wr, dtype=np.float64) / w_rate
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types,
                               np.zeros(n_wr, np.uint8)])
    keys = np.concatenate([pop, spec.keys, writer_keys])
    scan_lens = np.concatenate([np.zeros(pop.shape[0], np.int32),
                                spec.scan_lens,
                                np.zeros(n_wr, np.int32)])
    arrivals = np.concatenate([load_arrivals, run_arrivals, writer_arrivals])
    order = np.argsort(arrivals, kind="stable")
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    t0 = time.perf_counter()
    res = sim.run(op_types[order], keys[order], arrivals[order],
                  scan_lens=scan_lens[order])
    wall = time.perf_counter() - t0
    sc = res.op_types == OpKind.SCAN
    n_scans = max(1, int(sc.sum()))
    # Stall columns cover the measured (while-writing) phase only — the
    # load flood stalls every policy by construction and would otherwise
    # drown the writer's signal.  Load ops arrive first, so run-phase ops
    # are exactly the indices >= the population size.
    run_stalls = [d for i, d in sim.stall_events if i >= pop.shape[0]]
    return {
        "bench": "seekrandom", "workload": "run_e_while_writing",
        "policy": cfg.policy.value, "ops": n_ops,
        "write_rate_ops_s": int(w_rate),
        "p99_scan_ms": round(res.pct(99, op=int(OpKind.SCAN)) * 1e3, 3),
        "p50_scan_ms": round(res.pct(50, op=int(OpKind.SCAN)) * 1e3, 3),
        "scan_blocks_per_op": round(sim.stats.scan_blocks / n_scans, 2),
        "scan_files_per_op": round(float(res.get_probed[sc].mean()), 2),
        "stall_total_s": round(sum(run_stalls), 4),
        "stall_max_ms": round(max(run_stalls, default=0.0) * 1e3, 2),
        "wall_clock_s": round(wall, 3),
        "index_backend": cfg.index_backend or level_index.get_backend(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_dbbench.json",
                    help="write JSON rows here ('' disables)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (~10x fewer ops)")
    args = ap.parse_args(argv)
    scale = 1 << 18
    n_fill = 12_000 if args.quick else 120_000
    n_read = 20_000 if args.quick else 200_000
    n_pop = 10_000 if args.quick else 100_000
    n_scan = 4_000 if args.quick else 40_000
    n_scan_pop = 10_000 if args.quick else 60_000

    rows = []
    for dist in ("uniform", "pareto"):
        for name, cfg in (
                ("vlsm", LSMConfig.vlsm_default(scale=scale)),
                ("rocksdb", LSMConfig.rocksdb_default(scale=scale)),
                ("adoc", LSMConfig.adoc_default(scale=scale))):
            row = fillrandom(cfg, n_fill, dist=dist, scale=scale)
            rows.append(row)
            print(f"db_bench.{dist}.{name}: {row}")
    for name, cfg in (("vlsm", LSMConfig.vlsm_default(scale=scale)),
                      ("rocksdb_io", LSMConfig.rocksdb_io_default(scale=scale))):
        row = read_path(cfg, n_read, n_pop, scale=scale)
        rows.append(row)
        print(f"db_bench.read_path.{name}: {row}")
    # seekrandom / YCSB-E: scan tails for ALL five policies at the same
    # memory budget (same `scale`) and the same request rate.
    for name, cfg in (
            ("vlsm", LSMConfig.vlsm_default(scale=scale)),
            ("rocksdb", LSMConfig.rocksdb_default(scale=scale)),
            ("rocksdb_io", LSMConfig.rocksdb_io_default(scale=scale)),
            ("adoc", LSMConfig.adoc_default(scale=scale)),
            ("lsmi", LSMConfig.lsmi_default(scale=scale))):
        row = seekrandom(cfg, n_scan, n_scan_pop, scale=scale)
        rows.append(row)
        print(f"db_bench.seekrandom.{name}: {row}")
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1))
        print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()

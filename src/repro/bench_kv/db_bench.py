"""db_bench-style driver (paper §5: Meta-datacenter population runs).

``fillrandom`` populates the store to a target level-fill (the paper fills
all levels but the last) under uniform or Pareto key popularity and
reports I/O amplification — the paper measures only amplification with
db_bench, as do we.

    PYTHONPATH=src python -m repro.bench_kv.db_bench
"""

from __future__ import annotations

import numpy as np

from repro.core import DeviceModel, LSMConfig, Simulator

from .workloads import load_keys, pareto_keys


def fillrandom(cfg: LSMConfig, n_ops: int, *, dist: str = "uniform",
               scale: int | None = None, seed: int = 7) -> dict:
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    base = load_keys(n_ops, seed)
    keys = base if dist == "uniform" else pareto_keys(base, n_ops, seed=seed)
    arrivals = np.arange(n_ops) / 1e6          # flood: amp-only measurement
    res = sim.run(np.zeros(n_ops, np.uint8), keys, arrivals)
    st = res.stats
    return {
        "dist": dist, "policy": cfg.policy.value, "ops": n_ops,
        "io_amp": round(st.io_amp, 2), "write_amp": round(st.write_amp, 2),
        "levels_filled": sum(1 for s in sim.trees[0].level_sizes() if s > 0),
        "compactions": sum(st.compactions_per_level.values()),
    }


def main():
    scale = 1 << 18
    n = 120_000   # fills all levels but the last at this scale
    for dist in ("uniform", "pareto"):
        for name, cfg in (
                ("vlsm", LSMConfig.vlsm_default(scale=scale)),
                ("rocksdb", LSMConfig.rocksdb_default(scale=scale)),
                ("adoc", LSMConfig.adoc_default(scale=scale))):
            row = fillrandom(cfg, n, dist=dist, scale=scale)
            print(f"db_bench.{dist}.{name}: {row}")


if __name__ == "__main__":
    main()

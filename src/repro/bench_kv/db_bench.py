"""db_bench-style driver (paper §5: Meta-datacenter population runs).

``fillrandom`` populates the store to a target level-fill (the paper fills
all levels but the last) under uniform or Pareto key popularity and
reports I/O amplification — the paper measures only amplification with
db_bench, as do we.  ``read_path`` is the read-side companion: a
read-heavy YCSB-C run that times the DES wall-clock end-to-end, tracking
the batched LevelIndex GET path.  ``ycsb_a`` measures mixed-workload
(50% read / 50% update) tails, ``seekrandom`` scan tails while a writer
streams, ``chain_report`` is the chain observatory — per-policy
compaction-chain width/length/critical-path distributions on the same
fillrandom stream (paper §3, Figs 2 & 9) — and ``shard_sweep`` drives the
sharded fleet: YCSB-A at a FIXED aggregate rate over 1/2/4 hash shards
contending for one device (fleet P99/P99.9 vs shard count), plus a
Zipf hot-shard scenario whose per-shard breakdown shows one shard's
chains soaking up the stall attribution while every shard's read tail
rides the same busy device.  ``--bench name[,name...]`` restricts the
sweep; row schemas are documented in ``docs/benchmarks.md``.

Policies are resolved from the registry (``repro.core.policies``): every
registered policy — including ones registered after this file was written
— gets a row per bench.  ``--policy name[,name...]`` restricts the sweep.

Results are persisted as machine-readable JSON rows (policy, io_amp,
p99s, sim wall-clock) so the perf trajectory is diffable across commits:

    PYTHONPATH=src python -m repro.bench_kv.db_bench --json BENCH_dbbench.json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import DeviceModel, LSMConfig, OpKind, Simulator
from repro.core import level_index
from repro.core.policies import get_policy, names as policy_names, \
    resolve_names

from .workloads import (load_keys, make_run_a, make_run_c, make_run_e,
                        pareto_keys)


def fill_sim(cfg: LSMConfig, n_ops: int, dist: str = "uniform",
             scale: int | None = None, seed: int = 7
             ) -> tuple[Simulator, "object", float]:
    """Shared fillrandom drive (flood arrivals): returns (sim, res, wall).

    ``fillrandom`` and ``chain_report`` both report off this; pass the
    triple to either via ``run=`` to derive both rows from ONE simulation
    instead of running the identical fill twice."""
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    base = load_keys(n_ops, seed)
    keys = base if dist == "uniform" else pareto_keys(base, n_ops, seed=seed)
    arrivals = np.arange(n_ops) / 1e6          # flood: amp-only measurement
    t0 = time.perf_counter()
    res = sim.run(np.zeros(n_ops, np.uint8), keys, arrivals)
    return sim, res, time.perf_counter() - t0


def fillrandom(cfg: LSMConfig, n_ops: int, *, dist: str = "uniform",
               scale: int | None = None, seed: int = 7, run=None) -> dict:
    sim, res, wall = run or fill_sim(cfg, n_ops, dist, scale, seed)
    st = res.stats
    return {
        "bench": "fillrandom", "dist": dist, "policy": cfg.policy,
        "ops": n_ops,
        "io_amp": round(st.io_amp, 2), "write_amp": round(st.write_amp, 2),
        "levels_filled": sum(1 for s in sim.trees[0].level_sizes() if s > 0),
        "compactions": sum(st.compactions_per_level.values()),
        "wall_clock_s": round(wall, 3),
    }


def chain_report(cfg: LSMConfig, n_ops: int, *, dist: str = "uniform",
                 scale: int | None = None, seed: int = 7, run=None) -> dict:
    """Chain observatory (paper §3, Figs 2 & 9): drive fillrandom and
    report the chain ledger's width/length/critical-path distributions.

    Width is the chain head's L0 fan-in (tiering designs merge all of L0
    at once — wide; incremental designs pop one SST — narrow, the paper's
    narrow-chain claim), length the levels a chain traverses, and
    ``effective_length`` folds in the debt catch-up that debt designs
    defer into background sweeps.  Critical path is the device wall-clock
    from the chain's first stage start to its head finish, as scheduled
    by the chain-aware DES pool; ``stall_attributed_s`` is the foreground
    write-stop time the DES pinned on each chain."""
    sim, res, wall = run or fill_sim(cfg, n_ops, dist, scale, seed)
    row = {
        "bench": "chain_report", "workload": "fillrandom", "dist": dist,
        "policy": cfg.policy, "ops": n_ops,
    }
    row.update(res.chain_report())
    row["wall_clock_s"] = round(wall, 3)
    return row


def read_path(cfg: LSMConfig, n_ops: int = 200_000, n_pop: int = 100_000, *,
              scale: int | None = None, rate: float = 1e4,
              seed: int = 7) -> dict:
    """Read-heavy YCSB-C probe (zipfian GETs over a preloaded store): the
    wall-clock of the whole DES run is the tracked quantity — it is
    dominated by the GET path, one ``LSMTree.get_batch`` per window."""
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_c(pop, n_ops, dist="zipfian", seed=seed + 5)
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    arrivals = np.arange(op_types.shape[0], dtype=np.float64) / rate
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    t0 = time.perf_counter()
    res = sim.run(op_types, keys, arrivals)
    wall = time.perf_counter() - t0
    g = res.op_types == 1
    return {
        "bench": "read_path", "workload": "run_c",
        "policy": cfg.policy, "ops": n_ops,
        "wall_clock_s": round(wall, 3),
        "p99_get_ms": round(res.pct(99, op=1) * 1e3, 3),
        "p999_get_ms": round(res.pct(99.9, op=1) * 1e3, 3),
        "device_reads": int(sim.stats.device_reads),
        "mean_ssts_probed": round(float(res.get_probed[g].mean()), 3),
        "index_backend": cfg.index_backend or level_index.get_backend(),
    }


def _load_settle_run(n_load: int, n_run: int, rate: float,
                     settle_s: float) -> tuple[np.ndarray, np.ndarray]:
    """Shared open-loop arrival scaffolding for the measured benches:
    load-phase flood (1M ops/s), a ``settle_s`` compaction settle (YCSB's
    wait between load and run), then the measured run at ``rate``."""
    load = np.arange(n_load, dtype=np.float64) / 1e6
    run = load[-1] + settle_s + np.arange(n_run, dtype=np.float64) / rate
    return load, run


def _run_phase_stalls(sim: Simulator, n_load: int) -> list[float]:
    """Stall durations of the measured phase only — the load flood stalls
    every policy by construction and would drown the signal.  Load ops
    arrive first, so run-phase ops are exactly the indices >= n_load."""
    return [d for i, d in sim.stall_events if i >= n_load]


def seekrandom(cfg: LSMConfig, n_ops: int = 40_000, n_pop: int = 60_000, *,
               scale: int | None = None, rate: float = 300.0,
               write_rate: float = 800.0, settle_s: float = 30.0,
               seed: int = 7) -> dict:
    """Scan-tail probe: YCSB-E SCANs measured while a writer streams —
    db_bench's ``seekrandomwhilewriting`` counterpart.

    Methodology: load-phase flood, a ``settle_s`` compaction settle
    (YCSB's wait between load and run), then the measured run: the YCSB-E
    mix (95% zipfian SCANs / 5% inserts) arrives at ``rate`` while a
    background writer streams fresh keys at the same fixed ``write_rate``
    for every policy (db_bench's ``--benchmark_write_rate_limit``
    convention; the default sits inside every policy's sustainable region
    at the benchmark scale).  The scan tail then captures how each
    policy's compaction behaviour — chain width, write stalls, device
    busy time — bleeds into foreground range queries: the paper's
    read-tail mechanism (P99 reads up to 12.5x), extended to scans.
    vLSM's narrow chains keep its scan P99 low while its continuous small
    compactions cost a little median; RocksDB's wide tiering merges stall
    the queue and blow up the tail."""
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    w_rate = write_rate
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_e(pop, n_ops, dist="zipfian", seed=seed + 3)
    load_arrivals, run_arrivals = _load_settle_run(pop.shape[0], n_ops,
                                                   rate, settle_s)
    t_run = run_arrivals[0]
    n_wr = int(n_ops / rate * w_rate)
    writer_keys = load_keys(n_wr, seed + 9)
    writer_arrivals = t_run + np.arange(n_wr, dtype=np.float64) / w_rate
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types,
                               np.zeros(n_wr, np.uint8)])
    keys = np.concatenate([pop, spec.keys, writer_keys])
    scan_lens = np.concatenate([np.zeros(pop.shape[0], np.int32),
                                spec.scan_lens,
                                np.zeros(n_wr, np.int32)])
    arrivals = np.concatenate([load_arrivals, run_arrivals, writer_arrivals])
    order = np.argsort(arrivals, kind="stable")
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    t0 = time.perf_counter()
    res = sim.run(op_types[order], keys[order], arrivals[order],
                  scan_lens=scan_lens[order])
    wall = time.perf_counter() - t0
    sc = res.op_types == OpKind.SCAN
    n_scans = max(1, int(sc.sum()))
    run_stalls = _run_phase_stalls(sim, pop.shape[0])
    return {
        "bench": "seekrandom", "workload": "run_e_while_writing",
        "policy": cfg.policy, "ops": n_ops,
        "write_rate_ops_s": int(w_rate),
        "p99_scan_ms": round(res.pct(99, op=int(OpKind.SCAN)) * 1e3, 3),
        "p999_scan_ms": round(res.pct(99.9, op=int(OpKind.SCAN)) * 1e3, 3),
        "p50_scan_ms": round(res.pct(50, op=int(OpKind.SCAN)) * 1e3, 3),
        "scan_blocks_per_op": round(sim.stats.scan_blocks / n_scans, 2),
        "scan_files_per_op": round(float(res.get_probed[sc].mean()), 2),
        "stall_total_s": round(sum(run_stalls), 4),
        "stall_max_ms": round(max(run_stalls, default=0.0) * 1e3, 2),
        "wall_clock_s": round(wall, 3),
        "index_backend": cfg.index_backend or level_index.get_backend(),
    }


def ycsb_a(cfg: LSMConfig, n_ops: int = 60_000, n_pop: int = 60_000, *,
           scale: int | None = None, rate: float = 2_500.0,
           settle_s: float = 10.0, seed: int = 7) -> dict:
    """YCSB-A mixed tails (50% zipfian GET / 50% update, §6.3 / Fig 12).

    Load-phase flood, a short compaction settle, then the measured run at
    a fixed arrival rate common to every policy — the open-loop,
    coordinated-omission-free methodology.  The default rate sits inside
    every policy's sustainable region at the benchmark scale (the same
    fixed-rate convention as ``seekrandom``'s writer), so tails compare
    compaction interference rather than queue divergence.  The update
    half keeps compactions continuously in play, so the GET tail captures
    each policy's compaction interference: the paper's read-tail
    mechanism (P99 reads up to 12.5x between policies)."""
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_a(pop, n_ops, dist="zipfian")
    load_arrivals, run_arrivals = _load_settle_run(pop.shape[0], n_ops,
                                                   rate, settle_s)
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    arrivals = np.concatenate([load_arrivals, run_arrivals])
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    t0 = time.perf_counter()
    res = sim.run(op_types, keys, arrivals)
    wall = time.perf_counter() - t0
    n_load = pop.shape[0]
    run_lat = res.latency[n_load:]
    run_types = res.op_types[n_load:]
    get_lat = run_lat[run_types == OpKind.GET]
    put_lat = run_lat[run_types == OpKind.PUT]
    run_stalls = _run_phase_stalls(sim, n_load)
    return {
        "bench": "ycsb_a", "workload": "run_a", "dist": "zipfian",
        "policy": cfg.policy, "ops": n_ops, "rate_ops_s": int(rate),
        "p50_get_ms": round(float(np.percentile(get_lat, 50)) * 1e3, 3),
        "p99_get_ms": round(float(np.percentile(get_lat, 99)) * 1e3, 3),
        "p999_get_ms": round(float(np.percentile(get_lat, 99.9)) * 1e3, 3),
        "p99_put_ms": round(float(np.percentile(put_lat, 99)) * 1e3, 3),
        "p999_put_ms": round(float(np.percentile(put_lat, 99.9)) * 1e3, 3),
        "stall_total_s": round(sum(run_stalls), 4),
        "n_stalls": len(run_stalls),
        "io_amp": round(sim.stats.io_amp, 2),
        "wall_clock_s": round(wall, 3),
    }


def _sweep_row(cfg: LSMConfig, res, *, n_ops: int, n_load: int, rate: float,
               dist: str, wall: float, bench: str = "shard_sweep") -> dict:
    """Build one shard_sweep-schema row from a :class:`SimResult` alone
    (works for the serial engine and for fleet temporal passes: stall
    events and per-shard chain snapshots ride on the result)."""
    run_lat = res.latency[n_load:]
    run_types = res.op_types[n_load:]
    shard_ids = res.shard_ids if res.shard_ids is not None \
        else np.zeros(res.op_types.shape[0], np.int64)
    run_shards = shard_ids[n_load:]
    get_lat = run_lat[run_types == OpKind.GET]
    put_lat = run_lat[run_types == OpKind.PUT]
    run_stalls = [d for i, d in res.stall_events if i >= n_load]
    per_shard = []
    for s in range(cfg.n_shards):
        m = run_shards == s
        gl = run_lat[m & (run_types == OpKind.GET)]
        s_stalls = [d for i, d in res.stall_events
                    if i >= n_load and shard_ids[i] == s]
        per_shard.append({
            "shard": s,
            "ops": int(m.sum()),
            "p99_get_ms": round(float(np.percentile(gl, 99)) * 1e3, 3)
            if gl.size else 0.0,
            "stall_s": round(sum(s_stalls), 4),
            # write-stop time the DES pinned on this shard's chains
            # (whole run: chains are load-born but stall the run phase)
            "chain_stall_s": round(res.chain_stall_s[s], 4),
            "n_chains": res.chain_counts[s],
        })
    run_ops = np.array([p["ops"] for p in per_shard], np.float64)
    return {
        "bench": bench, "workload": "run_a", "dist": dist,
        "policy": cfg.policy, "n_shards": cfg.n_shards,
        "router": cfg.shard_router, "ops": n_ops, "rate_ops_s": int(rate),
        "p99_get_ms": round(float(np.percentile(get_lat, 99)) * 1e3, 3),
        "p999_get_ms": round(float(np.percentile(get_lat, 99.9)) * 1e3, 3),
        "p99_put_ms": round(float(np.percentile(put_lat, 99)) * 1e3, 3),
        "p999_put_ms": round(float(np.percentile(put_lat, 99.9)) * 1e3, 3),
        "stall_total_s": round(sum(run_stalls), 4),
        "n_stalls": len(run_stalls),
        "io_amp": round(res.stats.io_amp, 2),
        "hot_shard_frac": round(
            float(run_ops.max() / max(1.0, run_ops.sum())), 3),
        "per_shard": per_shard,
        "wall_clock_s": round(wall, 3),
    }


def shard_sweep(cfg: LSMConfig, n_ops: int = 30_000, n_pop: int = 40_000, *,
                dist: str = "uniform", scale: int | None = None,
                rate: float = 2_500.0, settle_s: float = 10.0,
                seed: int = 7) -> dict:
    """Sharded-fleet tails: YCSB-A at a fixed AGGREGATE rate over
    ``cfg.n_shards`` hash shards contending for one shared device.

    The aggregate arrival rate (and the device) is the same at every
    shard count, so the row isolates what partitioning itself buys or
    costs: each shard's memtable fills ``n_shards``× slower (fewer,
    later chains per shard) while every chain still runs on the shared
    compaction slots.  ``dist="zipf_ranked"`` with
    ``cfg.shard_router="range"`` is the hot-shard scenario — rank-ordered
    zipfian popularity co-locates the hot ranks in one shard's stripe
    (plain ``zipfian`` scatters them across hash shards and stays
    balanced), and the ``per_shard`` breakdown demonstrates the
    cross-shard interference mechanism: the hot shard's chains soak up
    the stall attribution (``chain_stall_s``) while the busy device
    inflates EVERY shard's read tail (``p99_get_ms`` of cold shards).
    """
    scale = scale or cfg.memtable_size
    lam = scale / (64 << 20)
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_a(pop, n_ops, dist=dist)
    load_arrivals, run_arrivals = _load_settle_run(pop.shape[0], n_ops,
                                                   rate, settle_s)
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    arrivals = np.concatenate([load_arrivals, run_arrivals])
    sim = Simulator(cfg, DeviceModel.scaled(lam))
    t0 = time.perf_counter()
    res = sim.run(op_types, keys, arrivals)
    wall = time.perf_counter() - t0
    return _sweep_row(cfg, res, n_ops=n_ops, n_load=pop.shape[0],
                      rate=rate, dist=dist, wall=wall)


def fleet_sweep_bench(policies: list[str], n_ops: int = 30_000,
                      n_pop: int = 40_000, *, dist: str = "uniform",
                      scale: int | None = None,
                      rates: tuple[float, ...] = None,
                      shard_counts: tuple[int, ...] = None,
                      settle_s: float = 10.0, seed: int = 7,
                      backend: str = "numpy",
                      serial_baseline: bool = True,
                      workers: int = 1, cache=None) -> list[dict]:
    """Policy × shard-count × arrival-rate matrix through the sweep
    executor (``repro.core.sweeps``) over the batched fleet engine,
    with the serial heap-loop as timed baseline and parity oracle.

    Every (policy, shard count) point shares ONE structural replay (or
    skips it on a structural-cache hit); each rate on the load curve is
    a cheap temporal pass over it.  ``workers > 1`` dispatches points
    over the executor's fork pool — rows are byte-identical at every
    worker count (namespace-isolated uid streams).  The serial baseline
    replays the full heap loop per (point, rate) — the
    paper-methodology cost of sweeping a fixed-rate load curve one run
    at a time — parallelized over the same pool.

    Emits one ``shard_sweep``-schema row per (point, rate) with
    ``bench="fleet_sweep"``/``engine="fleet"`` (``wall_clock_s`` is the
    fleet matrix wall amortized per run) carrying the executor's
    per-phase timing (``structural_s`` on the point's first rate row,
    ``temporal_s``/``lindley_s``/``finalize_s`` per rate, ``cache_hit``),
    then a summary row with the matrix walls, the measured speedup and
    the worst per-op latency parity gap against the serial oracle.

    ``backend`` picks the batched Lindley implementation ("numpy" by
    default: XLA's CPU scan lowering is ~20x slower than numpy's
    axis-1 accumulate on this tier; "jnp"/"pallas" are the device
    paths, parity-asserted in the kernel tests).
    """
    from repro.core import (SweepPoint, serial_sweep_parallel,
                            sweep_execute)
    if rates is None:
        rates = FLEET_RATES
    if shard_counts is None:
        shard_counts = FLEET_SHARD_COUNTS
    scale = scale or (1 << 18)
    lam = scale / (64 << 20)
    device = DeviceModel.scaled(lam)
    pop = np.unique(load_keys(n_pop, seed))
    spec = make_run_a(pop, n_ops, dist=dist)
    n_load = pop.shape[0]
    op_types = np.concatenate([np.zeros(n_load, np.uint8), spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    grid = []
    for rate in rates:
        load_arr, run_arr = _load_settle_run(n_load, n_ops, rate, settle_s)
        grid.append(np.concatenate([load_arr, run_arr]))
    points = [SweepPoint(label=f"{nm}/{k}",
                         cfg=get_policy(nm).default_config(scale=scale)
                         .with_(n_shards=k),
                         device=device, op_types=op_types, keys=keys,
                         arrivals_grid=grid)
              for nm in policies for k in shard_counts]
    n_runs = len(points) * len(rates)

    t0 = time.perf_counter()
    fleet_res, ftimings = sweep_execute(points, workers=workers,
                                        backend=backend, cache=cache)
    t_fleet = time.perf_counter() - t0

    rows = []
    for p, per_rate, ft in zip(points, fleet_res, ftimings):
        for ri, (rate, res) in enumerate(zip(rates, per_rate)):
            row = _sweep_row(p.cfg, res, n_ops=n_ops, n_load=n_load,
                             rate=rate, dist=dist, wall=t_fleet / n_runs,
                             bench="fleet_sweep")
            row["engine"] = "fleet"
            frag = ft.row(ri)
            row["structural_s"] = frag["structural_s"]
            row["temporal_s"] = frag["temporal_s"]
            row["lindley_s"] = frag["lindley_s"]
            row["finalize_s"] = frag["finalize_s"]
            row["cache_hit"] = frag["cache_hit"]
            rows.append(row)

    summary = {
        "bench": "fleet_sweep", "engine": "summary", "dist": dist,
        "policies": list(policies), "shard_counts": list(shard_counts),
        "n_rates": len(rates), "runs": n_runs, "ops": n_ops,
        "backend": backend, "workers": workers,
        "fleet_wall_s": round(t_fleet, 3),
        "wall_clock_s": round(t_fleet, 3),
    }
    if serial_baseline:
        t0 = time.perf_counter()
        serial_res = serial_sweep_parallel(points, workers=workers)
        t_serial = time.perf_counter() - t0
        dlat, stalls_eq = 0.0, True
        for pf, ps in zip(fleet_res, serial_res):
            for a, b in zip(pf, ps):
                dlat = max(dlat, float(np.max(np.abs(a.latency - b.latency))))
                stalls_eq &= (a.n_stalls == b.n_stalls)
        summary.update({
            "serial_wall_s": round(t_serial, 3),
            "speedup": round(t_serial / max(t_fleet, 1e-9), 2),
            "parity_max_abs_latency_s": float(dlat),
            "parity_stalls_equal": bool(stalls_eq),
            "wall_clock_s": round(t_fleet + t_serial, 3),
        })
    rows.append(summary)
    return rows


def make_serve_spec(*, duration_s: float = 4.0, population: int = 8_000,
                    seed: int = 7, admission: bool = False):
    """The pinned multi-tenant serve_sweep scenario (see docs/benchmarks.md).

    Three tenants over ``SERVE_SHARDS`` hash shards: a high-priority
    read-heavy tenant with a tight SLO (priority 0 — never shed), a
    bursty mixed tenant (priority 1), and a bulk write stream
    (priority 2 — shed first).  At ``load_factor`` 1.0 the aggregate
    offered rate is ``SERVE_BASE_RATE``; the factor axis scales every
    tenant's rate by compressing simulated time, sweeping across the
    saturation knee.
    """
    from repro.serving import AdmissionConfig, TenantSpec, TrafficSpec
    base = SERVE_BASE_RATE
    return TrafficSpec(
        tenants=(
            TenantSpec("prio", rate_ops_s=0.15 * base, mix="ycsb_b",
                       arrival="poisson", priority=0, slo_ms=25.0),
            TenantSpec("mid", rate_ops_s=0.35 * base, mix="ycsb_a",
                       arrival="bursty", priority=1, slo_ms=60.0),
            TenantSpec("bulk", rate_ops_s=0.5 * base, mix="load",
                       arrival="poisson", priority=2, slo_ms=250.0),
        ),
        duration_s=duration_s, population=population, seed=seed,
        admission=AdmissionConfig() if admission else None)


#: timing fragment for serve results that never went through the
#: executor (direct ``serve`` calls outside the grid path)
_NO_TIMING = {"structural_s": 0.0, "temporal_s": 0.0, "lindley_s": 0.0,
              "finalize_s": 0.0, "cache_hit": False}


def serve_row(cfg: LSMConfig, sr, *, factor: float, admission_on: bool,
              wall: float) -> dict:
    """One serve_sweep-schema row from a ``ServeResult``.

    Phase timing rides in ``sr.timing`` (set by ``serve_grid``):
    admission-off factors report the executor's per-phase split
    (``structural_s`` on the grid's first factor, or 0.0 on a
    structural-cache hit), admission-on factors run a serial engine
    with no phase split, so the whole run lands in ``structural_s``."""
    stream = sr.stream
    timing = sr.timing if sr.timing is not None else _NO_TIMING
    measured = (stream.tenant_ids >= 0) & ~np.isnan(sr.latency_full)
    get_lat = sr.latency_full[measured & (stream.op_types == OpKind.GET)]
    run_stalls = [d for i, d in sr.res.stall_events if i >= stream.n_load]
    per_tenant = []
    for ti, led in enumerate(sr.tenants):
        lat = sr.tenant_latency(ti)
        per_tenant.append({
            "tenant": led.name, "priority": led.priority,
            "slo_ms": led.slo_ms, "ops_offered": led.ops_offered,
            "shed_frac": round(led.shed_frac, 4),
            "throttled_frac": round(led.throttled_frac, 4),
            "slo_violation_frac": round(led.slo_violation_frac, 4),
            "goodput_ops_s": round(led.goodput_ops_s(sr.duration_s), 1),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3)
            if lat.size else 0.0,
            "p999_ms": round(float(np.percentile(lat, 99.9)) * 1e3, 3)
            if lat.size else 0.0,
        })
    return {
        "bench": "serve_sweep", "workload": "multi_tenant",
        "policy": cfg.policy, "n_shards": cfg.n_shards,
        "admission": "on" if admission_on else "off",
        "ops": int(sr.offered_ops), "load_factor": round(factor, 3),
        "offered_ops_s": round(sr.offered_ops_s, 1),
        "goodput_ops_s": round(sr.goodput_ops_s, 1),
        "shed_frac": round(sr.shed_frac, 4),
        "throttled_frac": round(sr.throttled_frac, 4),
        "slo_violation_frac": round(sr.slo_violation_frac, 4),
        "p99_get_ms": round(float(np.percentile(get_lat, 99)) * 1e3, 3)
        if get_lat.size else 0.0,
        "p999_get_ms": round(float(np.percentile(get_lat, 99.9)) * 1e3, 3)
        if get_lat.size else 0.0,
        "stall_total_s": round(sum(run_stalls), 4),
        "structural_s": timing["structural_s"],
        "temporal_s": timing["temporal_s"],
        "lindley_s": timing["lindley_s"],
        "finalize_s": timing["finalize_s"],
        "cache_hit": timing["cache_hit"],
        "per_tenant": per_tenant,
        "wall_clock_s": round(wall, 3),
    }


def serve_sweep_bench(policies: list[str], *, duration_s: float = 4.0,
                      population: int = 8_000,
                      factors: tuple[float, ...] = None,
                      scale: int | None = None, seed: int = 7,
                      workers: int = 1, cache=None) -> list[dict]:
    """Goodput-vs-offered-load curves per policy, admission off and on.

    The offered-load axis is swept with ``repro.serving.serve_grid``
    through the sweep executor: admission-off curves amortize ONE fleet
    structural replay per policy (the stream is factor-invariant, only
    arrivals compress) — or skip it entirely on a structural-cache hit —
    and admission-on points run a fresh serial engine each (the admitted
    subset differs per factor), dispatched over the executor's fork pool
    when ``workers > 1``.  Rows are byte-identical at every worker
    count.  Off curves show the open-loop collapse past the knee —
    vlsm's narrow chains push the knee right — and on curves show the
    controller buying bounded high-priority tails with ``shed_frac`` > 0.
    """
    from repro.serving import serve_grid
    if factors is None:
        factors = SERVE_FACTORS
    scale = scale or (1 << 18)
    lam = scale / (64 << 20)
    device = DeviceModel.scaled(lam)
    rows = []
    for nm in policies:
        for adm in (False, True):
            spec = make_serve_spec(duration_s=duration_s,
                                   population=population, seed=seed,
                                   admission=adm)
            cfg = get_policy(nm).default_config(scale=scale) \
                .with_(n_shards=SERVE_SHARDS)
            t0 = time.perf_counter()
            results = serve_grid(cfg, device, spec, factors,
                                 workers=workers, cache=cache)
            wall = (time.perf_counter() - t0) / len(factors)
            for f, sr in zip(factors, results):
                rows.append(serve_row(cfg, sr, factor=f, admission_on=adm,
                                      wall=wall))
    return rows


BENCHES = ("fillrandom", "read_path", "ycsb_a", "seekrandom",
           "chain_report", "shard_sweep", "fleet_sweep", "serve_sweep")
SHARD_COUNTS = (1, 2, 4)      # the sweep axis (fixed aggregate rate)
SWEEP_RATE = 5_000.0          # aggregate ops/s: stresses x1, easy at x4
# fleet_sweep: the batched-engine matrix — the rate axis is the paper's
# fixed-rate load curve, swept in one structural replay per point
FLEET_SHARD_COUNTS = (1, 2, 4, 16)
FLEET_RATES = tuple(
    float(r) for r in np.geomspace(1_250.0, 20_000.0, 32))
FLEET_RATES_QUICK = tuple(
    float(r) for r in np.geomspace(2_000.0, 8_000.0, 4))
# serve_sweep: the open-loop multi-tenant traffic layer — offered load
# swept by compressing simulated time (the stream is factor-invariant,
# so admission-off curves share one fleet structural replay per policy)
SERVE_BASE_RATE = 4_000.0     # aggregate offered ops/s at load_factor 1.0
SERVE_SHARDS = 2              # shards of the pinned serve scenario
SERVE_FACTORS = (0.5, 1.0, 1.5, 2.0, 2.5, 3.0)
SERVE_FACTORS_QUICK = (0.5, 1.5, 3.0)
HOT_SHARDS = 4                # shard count of the Zipf hot-shard scenario
HOT_RATE = 14_000.0           # hot scenario rate: the hot shard saturates
                              # and write-stops while its chains keep the
                              # shared device busy, inflating every
                              # shard's read tail


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_dbbench.json",
                    help="write JSON rows here ('' disables)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (~10x fewer ops)")
    ap.add_argument("--policy", default="all",
                    help="registry policy name(s), comma-separated, or "
                         f"'all' (registered: {', '.join(policy_names())})")
    ap.add_argument("--bench", default="all",
                    help="bench name(s), comma-separated, or 'all' "
                         f"(available: {', '.join(BENCHES)})")
    ap.add_argument("--seed", type=int, default=7,
                    help="base RNG seed for every workload (default 7)")
    ap.add_argument("--workers", type=int, default=1,
                    help="sweep-executor fork-pool size for fleet_sweep/"
                         "serve_sweep (1 = in-process; rows are "
                         "byte-identical at every worker count)")
    args = ap.parse_args(argv)
    seed = args.seed
    if args.bench == "all":
        benches = set(BENCHES)
    else:
        benches = {b.strip() for b in args.bench.split(",")}
        unknown = benches - set(BENCHES)
        if unknown:
            ap.error(f"unknown bench(es) {sorted(unknown)}; "
                     f"available: {', '.join(BENCHES)}")
    scale = 1 << 18
    n_fill = 12_000 if args.quick else 120_000
    n_read = 20_000 if args.quick else 200_000
    n_pop = 10_000 if args.quick else 100_000
    n_scan = 4_000 if args.quick else 40_000
    n_scan_pop = 10_000 if args.quick else 60_000
    n_mixed = 8_000 if args.quick else 60_000
    n_mixed_pop = 10_000 if args.quick else 60_000
    n_shard = 6_000 if args.quick else 30_000
    n_shard_pop = 8_000 if args.quick else 40_000

    # Resolve the policy sweep from the registry: a policy registered
    # tomorrow shows up in every bench below with zero edits here.
    # Unknown names exit with the registered list, not a KeyError trace.
    try:
        chosen = resolve_names(args.policy)
    except KeyError:
        ap.error(f"unknown policy name(s) in {args.policy!r}; "
                 f"registered: {', '.join(policy_names())}")

    def cfg_for(name: str) -> LSMConfig:
        return get_policy(name).default_config(scale=scale)

    # per-run executor accounting (feeds the perf_trajectory row below)
    from repro.core import DEFAULT_CACHE, LEDGER
    LEDGER.reset()
    rows = []
    # The uniform fillrandom runs are shared with chain_report (same cfg /
    # ops / dist / seed): one simulation feeds both rows.
    fill_runs: dict[str, tuple] = {}
    if "fillrandom" in benches:
        for dist in ("uniform", "pareto"):
            for name in chosen:
                cfg = cfg_for(name)
                run = fill_sim(cfg, n_fill, dist, scale, seed)
                if dist == "uniform":
                    fill_runs[name] = (cfg, run)
                row = fillrandom(cfg, n_fill, dist=dist, scale=scale,
                                 seed=seed, run=run)
                rows.append(row)
                print(f"db_bench.{dist}.{name}: {row}")
    if "read_path" in benches:
        for name in chosen:
            row = read_path(cfg_for(name), n_read, n_pop, scale=scale,
                            seed=seed)
            rows.append(row)
            print(f"db_bench.read_path.{name}: {row}")
    # ycsb_a: mixed read/update tails for every policy at the same memory
    # budget (same `scale`) and the same request rate.
    if "ycsb_a" in benches:
        for name in chosen:
            row = ycsb_a(cfg_for(name), n_mixed, n_mixed_pop, scale=scale,
                         seed=seed)
            rows.append(row)
            print(f"db_bench.ycsb_a.{name}: {row}")
    # seekrandom / YCSB-E: scan tails for every policy.
    if "seekrandom" in benches:
        for name in chosen:
            row = seekrandom(cfg_for(name), n_scan, n_scan_pop, scale=scale,
                             seed=seed)
            rows.append(row)
            print(f"db_bench.seekrandom.{name}: {row}")
    # chain_report: the chain observatory — width/length/critical-path
    # distributions per policy on the same fillrandom stream (the paper's
    # narrow-chain claim: vlsm mean width strictly below rocksdb's).
    if "chain_report" in benches:
        for name in chosen:
            cfg, run = fill_runs.get(name) or (cfg_for(name), None)
            row = chain_report(cfg, n_fill, scale=scale, seed=seed, run=run)
            rows.append(row)
            print(f"db_bench.chain_report.{name}: {row}")
    # shard_sweep: fleet P99/P99.9 vs shard count at a fixed aggregate
    # rate, then the Zipf hot-shard interference scenario at HOT_SHARDS.
    if "shard_sweep" in benches:
        for name in chosen:
            for k in SHARD_COUNTS:
                cfg = cfg_for(name).with_(n_shards=k)
                row = shard_sweep(cfg, n_shard, n_shard_pop, scale=scale,
                                  rate=SWEEP_RATE, seed=seed)
                rows.append(row)
                print(f"db_bench.shard_sweep.{name}.x{k}: {row}")
            # Zipf hot-shard: rank-ordered zipfian over the RANGE router
            # co-locates the hot ranks in one shard's stripe — the
            # canonical hot-shard skew.  The per_shard breakdown is the
            # cross-shard interference record: the hot shard saturates
            # and write-stops (chain_stall_s pins the time on its
            # chains) while the cold shards — no stalls of their own —
            # still see their read tails inflate on the busy device.
            cfg = cfg_for(name).with_(n_shards=HOT_SHARDS,
                                      shard_router="range")
            row = shard_sweep(cfg, n_shard, n_shard_pop, dist="zipf_ranked",
                              scale=scale, rate=HOT_RATE, seed=seed)
            rows.append(row)
            print(f"db_bench.shard_hot.{name}.x{HOT_SHARDS}: {row}")
    # fleet_sweep: the batched two-phase engine over the full policy x
    # shard-count x rate matrix — one structural replay per point, one
    # temporal pass per rate, batched Lindley for the whole matrix —
    # timed against the serial heap-loop oracle on the same matrix.
    if "fleet_sweep" in benches:
        frates = FLEET_RATES_QUICK if args.quick else FLEET_RATES
        fshards = (1, 4, 16) if args.quick else FLEET_SHARD_COUNTS
        frows = fleet_sweep_bench(chosen, n_shard, n_shard_pop,
                                  scale=scale, rates=frates,
                                  shard_counts=fshards, seed=seed,
                                  workers=args.workers,
                                  cache=DEFAULT_CACHE)
        rows.extend(frows)
        summ = frows[-1]
        print(f"db_bench.fleet_sweep: {summ}")
    # serve_sweep: goodput vs offered load for the pinned multi-tenant
    # scenario, admission off (open-loop collapse past the knee) and on
    # (priority-aware shedding keeps high-priority tails bounded).
    if "serve_sweep" in benches:
        sfactors = SERVE_FACTORS_QUICK if args.quick else SERVE_FACTORS
        sdur = 1.5 if args.quick else 4.0
        spop = 3_000 if args.quick else 8_000
        srows = serve_sweep_bench(chosen, duration_s=sdur, population=spop,
                                  factors=sfactors, scale=scale, seed=seed,
                                  workers=args.workers,
                                  cache=DEFAULT_CACHE)
        rows.extend(srows)
        for r in srows:
            if r["load_factor"] == sfactors[-1]:
                print(f"db_bench.serve_sweep.{r['policy']}."
                      f"adm_{r['admission']}.x{r['load_factor']}: "
                      f"goodput={r['goodput_ops_s']} "
                      f"shed={r['shed_frac']} "
                      f"p999_get_ms={r['p999_get_ms']}")
    # perf_trajectory: one machine-readable summary of this run's
    # executor activity — wall-clock vs the summed per-task compute (the
    # serial single-process cost of the same tasks), so the speedup the
    # pool + structural cache bought is diffable across commits.
    if LEDGER.tasks:
        row = {
            "bench": "perf_trajectory", "workers": args.workers,
            "tasks": LEDGER.tasks,
            "cache_hits": LEDGER.cache_hits,
            "cache_misses": LEDGER.cache_misses,
            "executor_wall_s": round(LEDGER.wall_s, 3),
            "serial_equiv_s": round(LEDGER.task_s, 3),
            "speedup": round(LEDGER.speedup, 2),
            "wall_clock_s": round(LEDGER.wall_s, 3),
        }
        rows.append(row)
        print(f"db_bench.perf_trajectory: {row}")
    # under REPRO_PARANOID_CHECKS=1, every row must match the schema
    # repro-lint extracts from this module's dict literals (B6xx) —
    # emitter drift fails the smoke run, not just the linter
    from repro.analysis.schemas import paranoid_validate_rows
    paranoid_validate_rows(rows)
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1))
        print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()

"""Workload drivers: YCSB (§5, open-loop modified YCSB) and db_bench."""

from .workloads import (WorkloadSpec, make_load_a, make_run_a, make_run_b,
                        make_run_c, make_run_d, zipf_keys)
from .ycsb import YCSBResult, run_ycsb, sustainable_throughput

__all__ = [
    "WorkloadSpec", "YCSBResult", "make_load_a", "make_run_a", "make_run_b",
    "make_run_c", "make_run_d", "run_ycsb", "sustainable_throughput",
    "zipf_keys",
]

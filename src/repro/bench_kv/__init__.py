"""Workload drivers: YCSB (§5, open-loop modified YCSB) and db_bench.

Op streams are typed (:class:`repro.core.OpKind`): PUT/GET/DELETE/SCAN;
``make_run_e`` is the scan-heavy YCSB-E mix.
"""

from .workloads import (WorkloadSpec, make_load_a, make_run_a, make_run_b,
                        make_run_c, make_run_d, make_run_e, pareto_keys,
                        zipf_keys)
from .ycsb import YCSBResult, run_ycsb, sustainable_throughput

__all__ = [
    "WorkloadSpec", "YCSBResult", "make_load_a", "make_run_a", "make_run_b",
    "make_run_c", "make_run_d", "make_run_e", "pareto_keys", "run_ycsb",
    "sustainable_throughput", "zipf_keys",
]

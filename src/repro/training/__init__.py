from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .step import make_prefill, make_serve_step, make_train_step

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "make_prefill",
           "make_serve_step", "make_train_step"]

"""train_step / serve_step factories — the functions the launcher jits.

``make_train_step`` returns ``step(params, opt_state, batch) -> (params,
opt_state, metrics)`` with value_and_grad over the scanned-remat forward,
AdamW, gradient clipping, and optional int8 gradient compression (error
feedback folded into opt_state — see distributed/compression.py).

``make_serve_step`` returns the single-token decode used by decode_32k /
long_500k; ``make_prefill`` the full-sequence prefill.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward, train_loss
from .optimizer import AdamWConfig, adamw_update, init_opt_state  # noqa: F401


def make_train_step(cfg, opt_cfg: AdamWConfig | None = None, *,
                    use_pallas: bool = False, remat: bool = True,
                    grad_accum: int = 1, compress_grads: bool = False):
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        return train_loss(cfg, params, batch, use_pallas=use_pallas,
                          remat=remat)

    def step(params, opt_state, batch):
        if grad_accum > 1:
            def micro(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zeros), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress_grads:
            from repro.distributed.compression import compress_tree
            grads, opt_state = compress_tree(grads, opt_state)
        params, opt_state, gnorm = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    return step


def make_serve_step(cfg, *, absorbed_mla: bool = True):
    def serve_step(params, tokens, pos, cache):
        logits, cache = decode_step(cfg, params, tokens, pos, cache,
                                    absorbed_mla=absorbed_mla)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return serve_step


def make_prefill(cfg, *, cache_len: int | None = None,
                 use_pallas: bool = False):
    def prefill(params, batch) -> Any:
        return forward(cfg, params, batch, mode="prefill",
                       cache_len=cache_len, use_pallas=use_pallas,
                       remat=False)
    return prefill

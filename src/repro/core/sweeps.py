"""Parallel sweep executor + content-addressed structural-replay cache.

A sweep matrix is a list of :class:`~repro.core.fleet.SweepPoint`\\ s;
PR 6's two-phase engine already amortizes the expensive structural
replay (phase A) over each point's arrival grid.  This layer adds the
two remaining amortizations:

* **Across processes** — :func:`sweep_execute` dispatches points over a
  fork-based worker pool.  Every engine is built with its own
  :class:`~repro.core.uids.UidNamespace`, so worker interleaving cannot
  perturb any uid stream: a fresh namespace starts from exactly the
  state ``reset_uid_counters()`` rewinds the module counters to, which
  makes the parallel rows byte-identical to the single-process path
  (``tests/test_sweeps.py`` pins workers=1 vs workers=4 across every
  registered policy).
* **Across calls** — :class:`StructuralCache` stores PREPARED engines
  (phase A done) under a content address: blake2b over the canonicalized
  ``LSMConfig`` (policy name included), the ``DeviceModel``, the region
  count and the raw op-stream bytes.  A hit skips phase A entirely and
  goes straight to ``temporal_pass`` + Lindley — sound because a
  temporal pass resets ALL pass-local state (the same mechanism
  ``traffic_curve`` relies on), so a cached engine returns the exact
  :class:`~repro.core.fleet.PendingRun` structures a fresh replay would.
  Arrival schedules are deliberately NOT part of the key: structure is
  arrival-independent (fleet.py's observation 2) — that independence is
  the amortization.

Every :func:`run_point` call reports per-phase wall-clock
(:class:`PointTiming`: ``structural_s`` / ``temporal_s`` / ``lindley_s``
/ ``finalize_s``) so the bench rows carry the win, and the module
:data:`LEDGER` accumulates executor wall vs summed per-task compute for
the machine-readable ``perf_trajectory`` row in BENCH_dbbench.json.

Forked workers inherit the parent's cache copy-on-write (hits on
pre-warmed entries are free); their own ``put``\\ s stay in the child,
so cross-point reuse inside one ``sweep_execute`` call only happens
when two points land on the same worker — the in-process ``workers=1``
path sees every hit.
"""

from __future__ import annotations

import dataclasses
import hashlib
import multiprocessing
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from .fleet import FleetEngine, SweepPoint
from .sim import SimResult, Simulator
from .uids import UidNamespace


# ------------------------------------------------------------- content key

def _digest_array(h, arr: np.ndarray | None) -> None:
    if arr is None:
        h.update(b"<none>")
        return
    a = np.ascontiguousarray(arr)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())


def point_key(point: SweepPoint) -> str:
    """Content address of a point's *structural* identity.

    Covers everything phase A depends on — policy name (an ``LSMConfig``
    field), the full canonicalized config, the device model, the region
    count and the op-stream arrays (types / keys / scan lens, raw
    bytes).  Arrivals are excluded on purpose: the structural replay is
    arrival-independent, so every schedule shares the cached engine.
    ``blake2b`` rather than builtin ``hash``: stable across processes
    and runs (the determinism contract ``repro-lint`` enforces).
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(repr(sorted(dataclasses.asdict(point.cfg).items())).encode())
    h.update(repr(sorted(dataclasses.asdict(point.device).items())).encode())
    h.update(str(int(point.n_regions)).encode())
    _digest_array(h, point.op_types)
    _digest_array(h, point.keys)
    _digest_array(h, point.scan_lens)
    return h.hexdigest()


# ------------------------------------------------------------------ cache

class StructuralCache:
    """Bounded LRU of prepared :class:`FleetEngine`\\ s, content-keyed.

    A ``get`` hit returns an engine whose phase A already ran for the
    exact (config, device, regions, op stream) content — safe to run
    ``temporal_pass`` on directly.  Entries hold the engine's full
    structural state (plans, pre-ranked batches, trees), so the default
    capacity is small; eviction is LRU.
    """

    def __init__(self, maxsize: int = 8):
        self.maxsize = maxsize
        self._entries: OrderedDict[str, FleetEngine] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> FleetEngine | None:
        eng = self._entries.get(key)
        if eng is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return eng

    def put(self, key: str, eng: FleetEngine) -> None:
        self._entries[key] = eng
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def stats(self) -> dict:
        return {"size": len(self._entries), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses}


#: the process-default cache ``run_point`` callers may share
DEFAULT_CACHE = StructuralCache()


# ----------------------------------------------------------------- timing

@dataclass
class PointTiming:
    """Per-phase wall-clock of one executed point.

    ``structural_s`` is phase A (0.0 on a cache hit); the three lists
    are per-grid-schedule (temporal pass, Lindley scan, finalize).
    """

    label: str
    cache_hit: bool
    structural_s: float
    temporal_s: list[float] = field(default_factory=list)
    lindley_s: list[float] = field(default_factory=list)
    finalize_s: list[float] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        """The point's whole compute (the serial-equivalent cost this
        task would contribute to a single-process run)."""
        return self.structural_s + sum(self.temporal_s) \
            + sum(self.lindley_s) + sum(self.finalize_s)

    def row(self, i: int) -> dict:
        """Phase-timing fragment for the point's i-th grid row.  Phase A
        is attributed to the first row only, so summing a point's rows
        never double-counts the shared structural replay."""
        return {
            "structural_s": round(self.structural_s if i == 0 else 0.0, 6),
            "temporal_s": round(self.temporal_s[i], 6),
            "lindley_s": round(self.lindley_s[i], 6),
            "finalize_s": round(self.finalize_s[i], 6),
            "cache_hit": bool(self.cache_hit),
        }


@dataclass
class ExecutorLedger:
    """Per-process running totals of executor activity.

    ``wall_s`` is executor wall-clock; ``task_s`` the summed per-task
    compute — what the same tasks would cost serially in one process —
    so ``speedup`` is the pool+cache win the ``perf_trajectory`` bench
    row records.
    """

    wall_s: float = 0.0
    task_s: float = 0.0
    tasks: int = 0
    cache_hits: int = 0
    cache_misses: int = 0

    def add(self, *, wall_s: float, timings: list[PointTiming]) -> None:
        self.wall_s += wall_s
        for t in timings:
            self.task_s += t.total_s
            self.tasks += 1
            if t.cache_hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    @property
    def speedup(self) -> float:
        return self.task_s / max(self.wall_s, 1e-9)

    def reset(self) -> None:
        self.wall_s = 0.0
        self.task_s = 0.0
        self.tasks = 0
        self.cache_hits = 0
        self.cache_misses = 0


#: accumulates across every sweep_execute / bench helper in the process
LEDGER = ExecutorLedger()


# -------------------------------------------------------------- run_point

def run_point(point: SweepPoint, *, backend: str = "numpy",
              cache: StructuralCache | None = None
              ) -> tuple[list[SimResult], PointTiming]:
    """Evaluate one sweep point: phase A (or a cache hit), then one
    temporal pass + Lindley + finalize per schedule in ``point.grid``.

    The engine is built with a fresh :class:`UidNamespace`, making the
    results byte-identical to the legacy ``reset_uid_counters()`` +
    module-counter path regardless of what else the process has run.
    Returns the per-schedule results and the point's :class:`PointTiming`.
    """
    from repro.kernels.lindley_scan.ops import lindley_batch_np
    key = point_key(point)
    eng = cache.get(key) if cache is not None else None
    hit = eng is not None
    structural = 0.0
    if eng is None:
        t0 = time.perf_counter()
        eng = FleetEngine(point.cfg, point.device,
                          n_regions=point.n_regions, uids=UidNamespace())
        eng.prepare_structural(point.op_types, point.keys, point.scan_lens)
        structural = time.perf_counter() - t0
        if cache is not None:
            cache.put(key, eng)
    timing = PointTiming(label=point.label, cache_hit=hit,
                         structural_s=structural)
    results: list[SimResult] = []
    for arr in point.grid:
        t0 = time.perf_counter()
        pd = eng.temporal_pass(arr)
        t1 = time.perf_counter()
        deps = lindley_batch_np([q[0] for q in pd.queues],
                                [q[1] for q in pd.queues], backend=backend)
        t2 = time.perf_counter()
        results.append(eng.finalize(deps, pending=pd))
        t3 = time.perf_counter()
        timing.temporal_s.append(t1 - t0)
        timing.lindley_s.append(t2 - t1)
        timing.finalize_s.append(t3 - t2)
    return results, timing


# ---------------------------------------------------------- fork-pool map

# Fork-inherited task state: set immediately before Pool creation so the
# children receive it copy-on-write (no per-task pickling of the big
# op-stream arrays); tasks are plain indices into it.
_FORK_STATE: tuple | None = None


def _point_task(i: int) -> tuple[list[SimResult], PointTiming]:
    points, backend, cache = _FORK_STATE
    return run_point(points[i], backend=backend, cache=cache)


def _serial_task(task: tuple[int, int]) -> SimResult:
    pi, ai = task
    points = _FORK_STATE[0]
    p = points[pi]
    sim = Simulator(p.cfg, p.device, n_regions=p.n_regions,
                    uids=UidNamespace())
    return sim.run(p.op_types, p.keys, p.grid[ai], p.scan_lens)


def _fork_map(fn, tasks: list, workers: int) -> list:
    ctx = multiprocessing.get_context("fork")
    with ctx.Pool(processes=min(workers, len(tasks))) as pool:
        return pool.map(fn, tasks)


def parallel_map(fn, items, *, workers: int = 1) -> list:
    """Order-preserving map with an optional fork pool.

    ``fn`` must be a module-level callable and ``items`` picklable when
    ``workers > 1`` (standard ``multiprocessing`` contract); ``workers
    <= 1`` is a plain in-process loop with no pool, no pickling.
    """
    items = list(items)
    if workers <= 1 or len(items) <= 1:
        return [fn(x) for x in items]
    return _fork_map(fn, items, workers)


# -------------------------------------------------------------- executors

def sweep_execute(points: list[SweepPoint], *, workers: int = 1,
                  backend: str = "numpy",
                  cache: StructuralCache | None = None
                  ) -> tuple[list[list[SimResult]], list[PointTiming]]:
    """Evaluate a sweep matrix through the executor.

    ``workers <= 1`` runs every point in-process (cache hits fully
    visible); ``workers > 1`` dispatches whole points over a fork pool —
    deterministic regardless of scheduling because every engine draws
    from its own uid namespace.  Returns ``(results, timings)`` with
    ``results[p]`` aligned to ``points[p].grid`` exactly like
    :func:`repro.core.fleet.fleet_sweep`, rows byte-identical to it.
    """
    global _FORK_STATE
    t0 = time.perf_counter()
    if workers <= 1 or len(points) <= 1:
        pairs = [run_point(p, backend=backend, cache=cache) for p in points]
    else:
        _FORK_STATE = (list(points), backend, cache)
        try:
            pairs = _fork_map(_point_task, list(range(len(points))),
                              workers)
        finally:
            _FORK_STATE = None
    wall = time.perf_counter() - t0
    results = [r for r, _ in pairs]
    timings = [t for _, t in pairs]
    LEDGER.add(wall_s=wall, timings=timings)
    return results, timings


def serial_sweep_parallel(points: list[SweepPoint], *,
                          workers: int = 1) -> list[list[SimResult]]:
    """:func:`repro.core.fleet.serial_sweep` (the heap-loop oracle, full
    structural replay per (point, rate)) with namespace-built engines
    and an optional fork pool over the flattened (point, rate) tasks.
    Byte-identical results to ``serial_sweep`` — the namespace ≡ reset
    equivalence — in the same per-point grouping."""
    global _FORK_STATE
    tasks = [(pi, ai) for pi, p in enumerate(points)
             for ai in range(len(p.grid))]
    _FORK_STATE = (list(points),)
    try:
        if workers <= 1 or len(tasks) <= 1:
            flat = [_serial_task(t) for t in tasks]
        else:
            flat = _fork_map(_serial_task, tasks, workers)
    finally:
        _FORK_STATE = None
    out: list[list[SimResult]] = []
    k = 0
    for p in points:
        n = len(p.grid)
        out.append(flat[k:k + n])
        k += n
    return out

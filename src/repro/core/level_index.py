"""LevelIndex: the vectorized fence/bloom manifest shared by every overlap
consumer in the store.

The paper's read-tail result hinges on how many SSTs a lookup probes per
level, and three different subsystems used to answer that question three
different ways: ``LSMTree.get`` walked per-level Python lists, compaction
picking re-scanned overlaps per candidate SST, and vSST planning ran fence
binary searches of its own.  This module centralizes the per-level fence
metadata once — flat numpy arrays (``smallest``, ``largest``, ``sizes``,
``uids``) mirroring each level's SST list, plus per-SST bloom seeds — and
serves every overlap/rank query from them, batched.

The arrays are maintained *incrementally* by the structural mutators
(flush appends to L0, ``_replace_in_level`` splices a contiguous span,
compaction removals delete by uid); queries never rebuild anything.

Rank queries are backend-switchable, mirroring ``repro.core.merge``:

* ``numpy``  — ``np.searchsorted``; the DES hot path.
* ``jnp``    — ``jnp.searchsorted`` under x64 (identical math on device).
* ``pallas`` — the ``repro.kernels.overlap_scan`` fence-rank TPU kernel
               (interpret mode on CPU); parity tests prove it drop-in.

Every query reduces to two rank primitives over sorted int64 fences:
``rank_left(a, v) = #{a < v}`` and ``rank_right(a, v) = #{a <= v}``; the SSTs
of a sorted disjoint level intersecting ``[lo, hi]`` are exactly positions
``[rank_left(largest, lo), rank_right(smallest, hi))``.
"""

from __future__ import annotations

import numpy as np

from .sst import SST

_BACKEND = "numpy"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("numpy", "jnp", "pallas")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


# Deterministic bloom-filter model: a (key, sst) pair pseudo-randomly false
# positives at the configured FPR.  The per-SST state is the mixed uid seed;
# identical to the scalar hash LSMTree._probe_sst historically used.
_KEY_MIX = np.uint64(0x9E3779B97F4A7C15)
_UID_MIX = np.uint64(0xBF58476D1CE4E5B9)
_MASK32 = np.uint64(0xFFFFFFFF)
_MAX32 = float(0xFFFFFFFF)


def bloom_seed_for_uid(uid) -> np.uint64:
    # wrap in Python ints: numpy warns on scalar uint64 overflow
    return np.uint64((int(uid) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF)


def bloom_false_positives(keys: np.ndarray, bloom_seed,
                          fpr: float) -> np.ndarray:
    """Boolean mask: which (key, sst) probes read a block despite a miss.

    ``bloom_seed`` is a scalar uint64 (one SST, many keys) or an array
    aligned with ``keys`` (one key per SST probe).
    """
    h = (np.asarray(keys).astype(np.uint64) * _KEY_MIX + bloom_seed) & _MASK32
    return (h.astype(np.float64) / _MAX32) < fpr


def _rank(arr: np.ndarray, vals: np.ndarray, side: str,
          backend: str | None = None) -> np.ndarray:
    """Backend-routed searchsorted over a sorted int64 fence array.

    side='right' counts ``arr <= v``; side='left' counts ``arr < v``.
    ``backend`` overrides the module default (an index constructed with an
    explicit backend keeps it regardless of the global switch).
    """
    backend = backend or _BACKEND
    vals = np.asarray(vals, np.int64)
    if arr.shape[0] == 0:
        return np.zeros(vals.shape, np.int64)
    if backend == "numpy":
        return np.searchsorted(arr, vals, side=side).astype(np.int64)
    if backend == "jnp":
        import jax
        import jax.numpy as jnp
        with jax.experimental.enable_x64():
            out = jnp.searchsorted(jnp.asarray(arr, jnp.int64),
                                   jnp.asarray(vals, jnp.int64), side=side)
            return np.asarray(out, np.int64)
    from repro.kernels.overlap_scan.ops import (fence_rank_np,
                                                fence_rank_strict_np)
    rank = fence_rank_np if side == "right" else fence_rank_strict_np
    return rank(arr, vals.ravel()).astype(np.int64).reshape(vals.shape)


def _fields(ssts: list[SST]) -> tuple[np.ndarray, ...]:
    n = len(ssts)
    small = np.fromiter((s.smallest for s in ssts), np.int64, n)
    large = np.fromiter((s.largest for s in ssts), np.int64, n)
    sizes = np.fromiter((s.size for s in ssts), np.int64, n)
    uids = np.fromiter((s.uid for s in ssts), np.int64, n)
    return small, large, sizes, uids


class LevelIndex:
    """Flat fence/bloom arrays mirroring ``LSMTree.levels``.

    Position ``i`` in every array of ``level`` corresponds to
    ``levels[level][i]``; levels >= 1 are sorted by key and disjoint, L0 is
    FIFO (append order) and may overlap.
    """

    def __init__(self, n_levels: int, backend: str | None = None):
        assert backend in (None, "numpy", "jnp", "pallas")
        self.n_levels = n_levels
        self.backend = backend       # None -> follow the module switch
        z = lambda: np.empty(0, np.int64)  # noqa: E731
        self.smallest = [z() for _ in range(n_levels)]
        self.largest = [z() for _ in range(n_levels)]
        self.sizes = [z() for _ in range(n_levels)]
        self.uids = [z() for _ in range(n_levels)]
        self.bloom = [np.empty(0, np.uint64) for _ in range(n_levels)]
        self._csum: list[np.ndarray | None] = [None] * n_levels
        # Per-level mutation counter: bumps on every structural update so
        # derived caches (the tree's flat key/seq concatenation feeding
        # the vectorized GET path) can invalidate lazily.
        self.version = [0] * n_levels

    # ------------------------------------------------ incremental updates
    def _set(self, level: int, small, large, sizes, uids) -> None:
        self.smallest[level] = small
        self.largest[level] = large
        self.sizes[level] = sizes
        self.uids[level] = uids
        self.bloom[level] = (uids.astype(np.uint64) * _UID_MIX)
        self._csum[level] = None
        self.version[level] += 1

    def refresh(self, level: int, ssts: list[SST]) -> None:
        """Bulk rebuild of one level's arrays (init / recovery path)."""
        self._set(level, *_fields(ssts))

    def l0_append(self, sst: SST) -> None:
        self._set(0,
                  np.append(self.smallest[0], sst.smallest),
                  np.append(self.largest[0], sst.largest),
                  np.append(self.sizes[0], sst.size),
                  np.append(self.uids[0], sst.uid))

    def l0_popleft(self) -> None:
        self._set(0, self.smallest[0][1:], self.largest[0][1:],
                  self.sizes[0][1:], self.uids[0][1:])

    def l0_clear(self) -> None:
        z = np.empty(0, np.int64)
        self._set(0, z, z.copy(), z.copy(), z.copy())

    def splice(self, level: int, start: int, end: int,
               new_ssts: list[SST]) -> None:
        """Replace positions [start, end) with ``new_ssts`` (sorted)."""
        small, large, sizes, uids = _fields(new_ssts)
        self._set(level,
                  np.concatenate([self.smallest[level][:start], small,
                                  self.smallest[level][end:]]),
                  np.concatenate([self.largest[level][:start], large,
                                  self.largest[level][end:]]),
                  np.concatenate([self.sizes[level][:start], sizes,
                                  self.sizes[level][end:]]),
                  np.concatenate([self.uids[level][:start], uids,
                                  self.uids[level][end:]]))

    def remove_uids(self, level: int, uids: list[int]) -> None:
        keep = ~np.isin(self.uids[level], np.asarray(uids, np.int64))
        self._set(level, self.smallest[level][keep], self.largest[level][keep],
                  self.sizes[level][keep], self.uids[level][keep])

    # ------------------------------------------------------------ queries
    def n_ssts(self, level: int) -> int:
        return int(self.uids[level].shape[0])

    def fences(self, level: int) -> tuple[np.ndarray, np.ndarray]:
        """(smallest, largest) fence arrays of a sorted, disjoint level."""
        return self.smallest[level], self.largest[level]

    def overlap_ranges(self, level: int, lo: np.ndarray, hi: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Per-query position slices [start, end) of the level's SSTs
        intersecting [lo_i, hi_i] (requires lo <= hi elementwise)."""
        starts = _rank(self.largest[level], lo, "left", self.backend)
        ends = _rank(self.smallest[level], hi, "right", self.backend)
        return starts, ends

    def overlap_slice(self, level: int, lo: int, hi: int) -> tuple[int, int]:
        s, e = self.overlap_ranges(level, np.asarray([lo], np.int64),
                                   np.asarray([hi], np.int64))
        return int(s[0]), int(e[0])

    def overlap_counts(self, level: int, lo: np.ndarray, hi: np.ndarray
                       ) -> np.ndarray:
        """#SSTs of ``level`` intersecting each [lo_i, hi_i] (the §4.2
        overlap quantity, vs this level's fences)."""
        starts, ends = self.overlap_ranges(level, lo, hi)
        return np.maximum(0, ends - starts)

    def scan_spans(self, level: int, start_keys: np.ndarray,
                   nbytes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Per-scan SST position spans [start_i, end_i) of a sorted level
        covering a forward range scan: from the first SST whose range can
        contain ``start_keys[i]`` (the same backend-routed fence rank that
        answers point overlaps) until the span holds >= ``nbytes[i]`` of
        data or the level ends."""
        starts = _rank(self.largest[level], start_keys, "left", self.backend)
        n = self.n_ssts(level)
        if n == 0:
            return starts, starts
        csum = self.size_prefix(level)
        need = csum[np.minimum(starts, n)] + np.asarray(nbytes, np.int64)
        ends = np.searchsorted(csum, need, side="left").astype(np.int64)
        return starts, np.clip(ends, starts, n)

    def size_prefix(self, level: int) -> np.ndarray:
        """csum[i] = total bytes of the level's first i SSTs (cached)."""
        if self._csum[level] is None:
            self._csum[level] = np.concatenate(
                [np.zeros(1, np.int64), np.cumsum(self.sizes[level])])
        return self._csum[level]

    def overlap_bytes(self, src_level: int, dst_level: int) -> np.ndarray:
        """Per src-SST: bytes of dst_level SSTs its key range intersects —
        the compaction-picking score numerator, one batched query."""
        starts, ends = self.overlap_ranges(dst_level, self.smallest[src_level],
                                           self.largest[src_level])
        csum = self.size_prefix(dst_level)
        return csum[ends] - csum[starts]

    # -------------------------------------------------------- validation
    def check_against(self, levels: list[list[SST]]) -> None:
        """Invariant: the mirror is in lock-step with the SST lists."""
        for level, ssts in enumerate(levels):
            small, large, sizes, uids = _fields(ssts)
            assert np.array_equal(self.smallest[level], small), \
                f"LevelIndex.smallest out of sync at L{level}"
            assert np.array_equal(self.largest[level], large), \
                f"LevelIndex.largest out of sync at L{level}"
            assert np.array_equal(self.sizes[level], sizes), \
                f"LevelIndex.sizes out of sync at L{level}"
            assert np.array_equal(self.uids[level], uids), \
                f"LevelIndex.uids out of sync at L{level}"

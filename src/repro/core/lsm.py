"""The LSM *mechanism* engine: memtable, flush, splice, merge, read paths.

Structural state (which SSTs live where) mutates *eagerly* when a compaction
is triggered; *time* is owned by the discrete-event simulation in
``repro.core.sim``, which schedules the :class:`Job` records this module
emits onto background compaction slots and derives stalls / P99 from their
completion times.  This split keeps the store's merge work 100% real (actual
sorted-array merges over actual keys — real overlaps, real vSST splits, real
amplification) while staying deterministic and replayable on CPU.

This module is **policy-agnostic**: every compaction *decision* — L0
strategy, level pick/scoring, SST sizing, stall/debt parameters, invariants
— is delegated to the ``CompactionPolicy`` object resolved from
``cfg.policy`` via the registry in :mod:`repro.core.policies` (the paper's
Fig 3 designs plus lazy leveling).  The strategy hooks call back into the
mechanism primitives exposed here: :meth:`LSMTree.overlap`,
:meth:`LSMTree.merge_runs`, :meth:`LSMTree.merge_down`,
:meth:`LSMTree.replace_in_level`, :meth:`LSMTree.strip_bottom_tombstones`,
and :meth:`LSMTree.emit_compact_job`.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from . import merge as merge_backend
from .level_index import LevelIndex, bloom_false_positives
from .memtable import Memtable
from .policies import get_policy
from .sst import SST, split_fixed, total_size, uid_allocator
from .stats import ChainRecord, Stats
from .types import (LSMConfig, OpKind, RequestBatch, ResultBatch,
                    seq_decode, seq_encode)
from .uids import UidNamespace

_job_ids = itertools.count()
# Chain ids are module-global (not per-tree): a Simulator shares one Stats
# ledger across regions, so chain identity must be unique across trees.
_chain_ids = itertools.count()


@dataclass
class Job:
    """A unit of background device work, scheduled by the DES.

    Every job carries its *chain identity*: ``chain_id`` names the
    compaction chain (or, for flushes, a fresh singleton id) and
    ``parent_job`` is the intra-chain predecessor this job's start must
    wait for (``None`` for the chain's deepest stage).  The DES respects
    the edge via ``parent_job.t_finish``; paranoid mode validates the
    lineage (acyclic, child starts >= parent finish).
    """

    kind: str                    # "flush" | "compact"
    level: int                   # source level (-1 for memtable flush)
    bytes_read: int
    bytes_written: int
    n_in_ssts: int
    n_out_ssts: int
    deps: list["Job"] = field(default_factory=list)
    uid: int = field(default_factory=lambda: next(_job_ids))
    l0_consumed: int = 0         # L0 SSTs this job removed (for the DES)
    chain_id: int = -1           # the chain this job belongs to
    parent_job: "Job | None" = None  # intra-chain predecessor (dep edge)
    shard: int = 0               # shard of the emitting tree (fleet DES)
    # filled by the DES:
    t_start: float = 0.0
    t_finish: float = 0.0
    scheduled: bool = False

    @property
    def total_bytes(self) -> int:
        return self.bytes_read + self.bytes_written


class LSMTree:
    """A single shard/region's LSM index.

    ``shard_id``/``region_id`` name the tree's place in a sharded fleet
    (both 0 for a standalone tree); every emitted :class:`Job` is
    stamped with the tree's ``shard_id`` (the DES keys compaction
    exclusivity on its own flat tree index).
    """

    def __init__(self, cfg: LSMConfig, stats: Stats | None = None,
                 shard_id: int = 0, region_id: int = 0,
                 uids: UidNamespace | None = None):
        self.cfg = cfg
        # The strategy object owning every compaction decision; the tree
        # itself is a policy-agnostic mechanism engine.
        self.policy = get_policy(cfg.policy)
        self.stats = stats if stats is not None else Stats()
        self.shard_id = shard_id
        self.region_id = region_id
        self.memtable = Memtable(cfg.memtable_size, cfg.kv_size)
        self.immutables: list[Memtable] = []
        # levels[0] is L0: FIFO, newest LAST; overlapping allowed.
        # levels[i>=1]: sorted by key, pairwise disjoint.
        self.levels: list[list[SST]] = [[] for _ in range(cfg.max_levels)]
        # The manifest: flat fence/bloom arrays mirroring ``levels``,
        # maintained incrementally by every structural mutation below and
        # serving ALL overlap queries (GETs, compaction picking, vSST fences).
        self.index = LevelIndex(cfg.max_levels, backend=cfg.index_backend)
        self.seq = 0
        self.pending_jobs: list[Job] = []
        # chain id the current compaction pass stamps onto emitted jobs
        self._active_chain = -1
        # SST uid source: tree slot 0 keeps the process-global counter
        # (preserving every single-tree uid stream, which the bloom-FP
        # hash mixes and the read-parity capture pins); every other tree
        # of a fleet draws from its own disjoint base so SST identity —
        # and therefore bloom behaviour — is independent of how an engine
        # interleaves trees in time (the heap DES and the batched fleet
        # engine replay the same per-tree structural order, not the same
        # global order).  An explicit ``uids`` namespace replaces the
        # process-global counters with engine-private ones starting at
        # the same (reset) state — byte-identical streams, immune to
        # allocations by any OTHER engine alive in the process.
        self._uids = uids
        slot = (shard_id << 12) | region_id
        if slot != 0:
            self._sst_uids = itertools.count(slot << 40)
        else:
            self._sst_uids = uids.sst_ids if uids is not None else None
        # Lazy flat concatenation of each sorted level's keys/seqs (the
        # vectorized GET path probes a whole level with ONE searchsorted);
        # invalidated by the LevelIndex per-level version counters.
        self._flat: dict[int, tuple[int, np.ndarray, np.ndarray]] = {}

    def _next_job_uid(self) -> int:
        """Job uid from the tree's namespace, or the module counter."""
        return next(self._uids.job_ids if self._uids is not None
                    else _job_ids)

    def _next_chain_id(self) -> int:
        """Chain id from the tree's namespace, or the module counter."""
        return next(self._uids.chain_ids if self._uids is not None
                    else _chain_ids)

    # --------------------------------------------------- typed entry point
    def apply_batch(self, batch: RequestBatch) -> ResultBatch:
        """THE operation entry point: apply one typed request batch.

        Writes (PUT + DELETE, in array order) land first, then GETs and
        SCANs observe the post-write state — matching the DES, whose window
        boundaries guarantee reads see every write that precedes them.
        ``put_batch`` / ``delete_batch`` / ``get_batch`` / ``scan_batch``
        are thin wrappers over this.  Assigned seqnos are also written back
        into ``batch.seqnos``.
        """
        kinds = batch.kinds
        n = len(batch)
        seqs_out = np.full(n, -1, np.int64)
        reads = np.zeros(n, np.int32)
        probed = np.zeros(n, np.int32)
        offsets = np.zeros(n + 1, np.int64)
        scan_keys = scan_seqs = np.empty(0, np.int64)
        w = batch.mask(OpKind.PUT, OpKind.DELETE)
        if w.any():
            widx = np.nonzero(w)[0]
            assigned = self._write_batch(batch.keys[widx],
                                         kinds[widx] == OpKind.DELETE)
            seqs_out[widx] = assigned
            batch.seqnos[widx] = assigned
        g = kinds == OpKind.GET
        if g.any():
            gidx = np.nonzero(g)[0]
            s, r, p = self._lookup_batch(batch.keys[gidx])
            seqs_out[gidx] = s
            reads[gidx] = r
            probed[gidx] = p
        sc = kinds == OpKind.SCAN
        if sc.any():
            sidx = np.nonzero(sc)[0]
            counts, r, p, scan_keys, scan_seqs = self._scan_impl(
                batch.keys[sidx], batch.scan_lens[sidx])
            seqs_out[sidx] = counts
            reads[sidx] = r
            probed[sidx] = p
            lens = np.zeros(n, np.int64)
            lens[sidx] = counts
            np.cumsum(lens, out=offsets[1:])
        return ResultBatch(kinds, seqs_out, reads, probed, offsets,
                           scan_keys, scan_seqs)

    # ------------------------------------------------------------ ingest
    def put_batch(self, keys: np.ndarray) -> np.ndarray:
        """Insert keys (must fit in the active memtable); returns their seqs."""
        return self.apply_batch(RequestBatch.puts(keys)).seqs

    def delete_batch(self, keys: np.ndarray) -> np.ndarray:
        """Write DELETE tombstones for keys; returns their seqs.  Markers
        flow memtable → SST → merges and are reclaimed at the bottom level."""
        return self.apply_batch(RequestBatch.deletes(keys)).seqs

    def _write_batch(self, keys: np.ndarray, tombs: np.ndarray) -> np.ndarray:
        """Append PUT/DELETE entries in array order; returns logical seqs."""
        n = int(keys.shape[0])
        assert n <= self.memtable.room, "caller must chunk at memtable capacity"
        seqs = np.arange(self.seq, self.seq + n, dtype=np.int64)
        self.seq += n
        tombs = np.asarray(tombs, bool)
        self.memtable.put_batch(np.asarray(keys, np.int64),
                                seq_encode(seqs, tombs))
        self.stats.user_bytes += n * self.cfg.kv_size
        self.stats.ops += n
        self.stats.delete_ops += int(tombs.sum())
        return seqs

    def seal_memtable(self) -> None:
        assert self.memtable.full or self.memtable.n > 0
        self.immutables.append(self.memtable)
        self.memtable = Memtable(self.cfg.memtable_size, self.cfg.kv_size)

    def flush_immutable(self) -> tuple[Job, list[Job]]:
        """Flush the oldest immutable memtable to L0.

        Returns ``(flush_job, chain_jobs)``: the flush itself, plus any
        compaction chain that had to be triggered because L0 was at its
        compaction trigger.  ``flush_job`` depends on the chain's head (the
        L0 compaction) when one was needed *and* L0 was at the stop limit.
        """
        with uid_allocator(self._sst_uids):
            return self._flush_immutable()

    def _flush_immutable(self) -> tuple[Job, list[Job]]:
        chain_jobs: list[Job] = []
        l0 = self.levels[0]
        if len(l0) >= self.cfg.l0_max_ssts:
            chain_jobs = self._compact_l0_trigger()
        blocking: list[Job] = []
        if (len(self.levels[0]) >= self.policy.l0_stop_ssts(self.cfg)
                and chain_jobs):
            blocking = [chain_jobs[-1]]  # chain head: the L0 compaction
        mt = self.immutables.pop(0)
        sst = mt.to_sst()
        # A flush is its own singleton chain: the dep on a compaction
        # chain's head (when L0 hit the stop limit) is cross-chain
        # back-pressure, not chain lineage, so parent_job stays None.
        if sst.n == 0:
            job = Job("flush", -1, 0, 0, 0, 0, deps=blocking,
                      uid=self._next_job_uid(),
                      chain_id=self._next_chain_id(), shard=self.shard_id)
            self.pending_jobs.append(job)
            return job, chain_jobs
        self.levels[0].append(sst)
        self.index.l0_append(sst)
        self.stats.flush_bytes += sst.size
        self.stats.ssts_created += 1
        self.stats.manifest_flushes += 1
        job = Job("flush", -1, 0, sst.size, 0, 1, deps=blocking,
                  uid=self._next_job_uid(),
                  chain_id=self._next_chain_id(), shard=self.shard_id)
        self.pending_jobs.append(job)
        return job, chain_jobs

    # ------------------------------------------------------- compactions
    def _compact_l0_trigger(self) -> list[Job]:
        """L0 is at its trigger: run the policy's L0 compaction until the
        file count is back below the trigger, recording each pass as a
        chain (deeper stages first within a pass; the overall last job is
        the final L0 stage).

        Tiering designs clear L0 wholesale in one pass.  Non-tiering
        designs pop ONE FIFO SST per pass, so after a burst piled up extra
        L0 SSTs the loop keeps draining — like a real compaction scheduler,
        which re-picks L0 while the file count scores at/above the trigger
        rather than once per flush.  In steady state the loop body runs
        exactly once, leaving structural sequencing on non-bursty traces
        unchanged.
        """
        all_jobs: list[Job] = []
        while len(self.levels[0]) >= self.cfg.l0_max_ssts:
            jobs, _stage_bytes = self._chain_pass(0, trigger="l0")
            if not jobs:
                break
            all_jobs.extend(jobs)
        return all_jobs

    def _chain_pass(self, level: int, trigger: str
                    ) -> tuple[list[Job], list[int]]:
        """Run ONE compaction pass from ``level`` as a first-class chain:
        allocate a chain id, stamp it on every job the pass emits, and
        ledger a :class:`ChainRecord` (width = head fan-in, length =
        distinct levels traversed, per-stage bytes).  The chain *head* is
        the final job of the pass — the one that relieves the trigger."""
        cid = self._next_chain_id()
        prev, self._active_chain = self._active_chain, cid
        try:
            jobs, stage_bytes = self._compact_from(level)
        finally:
            self._active_chain = prev
        if jobs:
            head = jobs[-1]
            # Paper width = the head's L0 fan-in (tiering merges all of
            # L0 at once, incremental pops one SST); background sweeps
            # have no L0 stage, so their head's total input fan-in stands.
            rec = self.stats.record_chain(ChainRecord(
                chain_id=cid, trigger=trigger,
                length=len({j.level for j in jobs}),
                width=head.l0_consumed or head.n_in_ssts,
                width_bytes=sum(j.total_bytes for j in jobs),
                stage_bytes=stage_bytes,
                n_jobs=len(jobs),
                job_uids=[j.uid for j in jobs],
            ))
            if self.cfg.paranoid_checks:
                self._check_chain(jobs, rec)
        return jobs, stage_bytes

    def _check_chain(self, jobs: list[Job], rec: ChainRecord) -> None:
        """Chain invariants at emission time: every job stamped with the
        record's id, parent lineage acyclic and contained in the chain,
        width >= 1, and width/length consistent with the job topology."""
        uids = {j.uid for j in jobs}
        head = jobs[-1]
        assert rec.width >= 1, "chain head must consume at least one SST"
        assert rec.length == len({j.level for j in jobs}), \
            "chain length must match the job topology"
        assert rec.width == (head.l0_consumed or head.n_in_ssts), \
            "chain width must be the head stage's L0 fan-in"
        for j in jobs:
            assert j.chain_id == rec.chain_id, "job missing its chain stamp"
            visited = {j.uid}
            p = j.parent_job
            while p is not None:
                assert p.uid not in visited, "cycle in chain parent lineage"
                assert p.uid in uids, "chain parent crosses chain boundary"
                visited.add(p.uid)
                assert len(visited) <= len(jobs)
                p = p.parent_job

    def _compact_from(self, level: int) -> tuple[list[Job], list[int]]:
        """Compact from ``level`` into ``level+1``, first ensuring space
        below (the dependent chain).  Deeper jobs precede shallower ones and
        the shallower job depends on them.  *What* gets compacted is the
        strategy object's call (``compact_l0`` / ``pick_compaction``)."""
        cfg = self.cfg
        jobs: list[Job] = []
        stage_bytes: list[int] = []
        incoming = self.policy.incoming_bytes(self, level)
        # Ensure the target level has room (unless it is the last level).
        if level + 1 < cfg.max_levels - 1:
            while (total_size(self.levels[level + 1]) + incoming
                   > self.policy.level_limit(cfg, level + 1)):
                sub, sub_stage = self._compact_from(level + 1)
                if not sub:
                    break
                jobs.extend(sub)
                stage_bytes.extend(sub_stage)
        deps = [jobs[-1]] if jobs else []
        if level == 0:
            job = self.policy.compact_l0(self, deps)
        else:
            job = self.policy.pick_compaction(self, level, deps)
        if job is not None:
            jobs.append(job)
            stage_bytes.append(job.total_bytes)
        return jobs, stage_bytes

    # --- mechanism primitives (the strategy objects' toolbox) ---------------
    def merge_runs(self, runs: list[tuple[np.ndarray, np.ndarray]]
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Latest-wins k-way merge through the configured backend, with the
        merged-key accounting every compaction stage charges."""
        keys, seqs = merge_backend.merge_runs(runs)
        self.stats.merged_keys += int(keys.shape[0])
        return keys, seqs

    def merge_down(self, level: int, picked_idx: list[int],
                   deps: list[Job]) -> Job | None:
        """Merge the picked SSTs from ``level`` into ``level+1``.

        Picked SSTs are grouped into *contiguous* runs (by position in the
        level) so that the merge never pulls in next-level SSTs that sit in
        an unpicked gap — that would spuriously inflate I/O amplification.
        All groups are accounted as ONE chain stage (one Job), matching the
        paper's "compacts a set of SSTs ... whose cumulative size equals
        S_M" as a single compaction.
        """
        if not picked_idx:
            return None
        cfg = self.cfg
        picked_idx = sorted(picked_idx)
        groups: list[list[SST]] = []
        run: list[int] = []
        for i in picked_idx:
            if run and i == run[-1] + 1:
                run.append(i)
            else:
                if run:
                    groups.append([self.levels[level][j] for j in run])
                run = [i]
        groups.append([self.levels[level][j] for j in run])

        read_b = write_b = n_in = n_out = 0
        for group in groups:
            lo = min(s.smallest for s in group)
            hi = max(s.largest for s in group)
            over = self.overlap(level + 1, lo, hi)
            runs = [(s.keys, s.seqs) for s in group]
            runs += [(s.keys, s.seqs) for s in over]
            keys, seqs = self.merge_runs(runs)
            keys, seqs = self.strip_bottom_tombstones(level + 1, keys, seqs)
            new = split_fixed(keys, seqs, cfg.kv_size, cfg.sst_size)
            self.replace_in_level(level + 1, over, new)
            guids = {s.uid for s in group}
            self.levels[level] = [s for s in self.levels[level]
                                  if s.uid not in guids]
            self.index.remove_uids(level, sorted(guids))
            read_b += total_size(group) + total_size(over)
            write_b += sum(s.size for s in new)
            n_in += len(group) + len(over)
            n_out += len(new)
        return self.emit_compact_job(level, read_b, write_b, n_in, n_out,
                                     deps)

    def strip_bottom_tombstones(self, target_level: int, keys: np.ndarray,
                                seqs: np.ndarray
                                ) -> tuple[np.ndarray, np.ndarray]:
        """Drop DELETE markers from a merge writing the bottom level — no
        older version can exist below it, so the marker is reclaimable."""
        if target_level != self.cfg.max_levels - 1 or keys.shape[0] == 0:
            return keys, seqs
        tomb = (seqs & 1).astype(bool)
        nd = int(tomb.sum())
        if nd == 0:
            return keys, seqs
        self.stats.tombstones_dropped += nd
        self.stats.tombstone_bytes_dropped += nd * self.cfg.kv_size
        keep = ~tomb
        return keys[keep], seqs[keep]

    def overlap(self, level: int, lo: int, hi: int) -> list[SST]:
        """SSTs of a sorted, disjoint level intersecting [lo, hi] — the
        manifest's fence query (always a contiguous slice)."""
        start, end = self.index.overlap_slice(level, lo, hi)
        return self.levels[level][start:end]

    def replace_in_level(self, level: int, old: list[SST],
                         new: list[SST]) -> None:
        """Splice ``new`` into the level where ``old`` (a contiguous span of
        the sorted level, possibly empty) sat; keeps the manifest arrays in
        lock-step incrementally."""
        new_live = [s for s in new if s.n > 0]
        lvl = self.levels[level]
        if old:
            old_ids = np.fromiter((s.uid for s in old), np.int64, len(old))
            pos = np.nonzero(np.isin(self.index.uids[level], old_ids))[0]
            start, end = int(pos[0]), int(pos[-1]) + 1
            assert pos.shape[0] == end - start, \
                "replaced SSTs must form a contiguous span"
        elif new_live:
            start = end = int(np.searchsorted(self.index.smallest[level],
                                              new_live[0].smallest))
        else:
            return
        self.levels[level] = lvl[:start] + new_live + lvl[end:]
        self.index.splice(level, start, end, new_live)

    def emit_compact_job(self, level: int, read_b: int, write_b: int,
                         n_in: int, n_out: int, deps: list[Job]) -> Job:
        self.stats.compact_bytes_read += read_b
        self.stats.compact_bytes_written += write_b
        self.stats.ssts_created += n_out
        self.stats.manifest_flushes += 1
        self.stats.note_compaction(level, read_b + write_b)
        job = Job("compact", level, read_b, write_b, n_in, n_out, deps=deps,
                  uid=self._next_job_uid(),
                  chain_id=self._active_chain,
                  parent_job=deps[0] if deps else None, shard=self.shard_id)
        self.pending_jobs.append(job)
        return job

    def background_triggers(self) -> list[Job]:
        """Soft over-target compactions (debt designs run these proactively;
        everyone runs them to converge after bursts).

        The strategy object sets the soft factor: debt designs (ADOC) let
        levels run *past* target and only compact in big batches once they
        exceed ``soft_limit_factor`` × target — trading I/O amplification
        (larger overlaps while overfull) for fewer stalls.
        """
        with uid_allocator(self._sst_uids):
            return self._background_triggers()

    def _background_triggers(self) -> list[Job]:
        jobs: list[Job] = []
        cfg = self.cfg
        soft = self.policy.soft_limit_factor
        for level in range(1, cfg.max_levels - 1):
            guard = 0
            while (total_size(self.levels[level])
                   > soft * self.policy.level_target(cfg, level)
                   and guard < 64):
                sub, _sb = self._chain_pass(level, trigger="background")
                if not sub:
                    break
                jobs.extend(sub)
                guard += 1
        return jobs

    def drain_jobs(self) -> list[Job]:
        if self.cfg.paranoid_checks and self.pending_jobs:
            # every structural mutation pass is validated before its jobs
            # reach the scheduler (on in tests, off in benchmarks)
            self.check_invariants()
        out, self.pending_jobs = self.pending_jobs, []
        return out

    # ------------------------------------------------------------- lookup
    def get(self, key: int) -> tuple[int | None, int, int]:
        """Point lookup.  Returns (seq|None, device_block_reads, ssts_probed).

        A single-key :meth:`get_batch`: memtables (free), L0 newest→oldest
        (every overlapping SST), then one fence-selected SST per level; a
        bloom filter screens device reads with deterministic false
        positives.  A key whose newest entry is a DELETE tombstone returns
        ``None`` (the marker's block read is still charged).
        """
        seqs, reads, probed = self.get_batch(np.asarray([key], np.int64))
        s = int(seqs[0])
        return (None if s < 0 else s), int(reads[0]), int(probed[0])

    def get_batch(self, keys: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized point lookups: ``(seqs, block_reads, ssts_probed)``.

        Thin wrapper over :meth:`apply_batch`; misses *and deleted keys*
        report seq ``-1``.  All fence selection runs through the
        :class:`LevelIndex` manifest, array-at-a-time.
        """
        res = self.apply_batch(RequestBatch.gets(keys))
        return res.seqs, res.reads, res.probed

    def _lookup_batch(self, keys: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        keys = np.ascontiguousarray(keys, dtype=np.int64)
        n = keys.shape[0]
        seqs = np.full(n, -1, np.int64)
        reads = np.zeros(n, np.int32)
        probed = np.zeros(n, np.int32)
        if n == 0:
            return seqs, reads, probed
        active = np.ones(n, bool)
        # Memtable probes are free (no device reads), newest first.
        for mt in [self.memtable] + self.immutables[::-1]:
            if not active.any():
                return seqs, reads, probed
            idx = np.nonzero(active)[0]
            got = mt.get_batch(keys[idx])
            hit = got >= 0
            if hit.any():
                hidx = idx[hit]
                log, tomb = seq_decode(got[hit])
                seqs[hidx] = np.where(tomb, -1, log)
                active[hidx] = False
        # L0 newest -> oldest: every range-overlapping SST is probed.
        l0 = self.levels[0]
        for p in range(len(l0) - 1, -1, -1):
            if not active.any():
                return seqs, reads, probed
            idx = np.nonzero(active)[0]
            k = keys[idx]
            inr = ((k >= self.index.smallest[0][p])
                   & (k <= self.index.largest[0][p]))
            if inr.any():
                self._probe_sst_batch(l0[p], self.index.bloom[0][p], idx[inr],
                                      keys, seqs, reads, probed, active)
        # Leveled: at most one fence-selected SST per level.  The level's
        # SSTs are sorted AND disjoint, so their concatenated key array is
        # globally sorted: ONE searchsorted over the flat level resolves
        # every candidate probe at once — the per-key accounting (probed,
        # block reads, bloom false positives keyed on the candidate SST's
        # seed) is element-for-element what the per-SST group loop did.
        for level in range(1, self.cfg.max_levels):
            if not active.any():
                break
            if self.index.n_ssts(level) == 0:
                continue
            idx = np.nonzero(active)[0]
            k = keys[idx]
            starts, ends = self.index.overlap_ranges(level, k, k)
            cand = ends > starts
            if not cand.any():
                continue
            cidx = idx[cand]
            cpos = starts[cand]
            fkeys, fseqs = self._flat_level(level)
            probed[cidx] += 1
            ck = keys[cidx]
            # A candidate's fences bracket the key, so the flat rank lands
            # inside that SST's block (no clipping needed) and a hit can
            # only be the candidate itself (level keys are unique).
            pos = np.searchsorted(fkeys, ck)
            found = fkeys[pos] == ck
            fidx = cidx[found]
            log, tomb = seq_decode(fseqs[pos[found]])
            seqs[fidx] = np.where(tomb, -1, log)
            reads[fidx] += 1     # bloom true positive -> one block read
            active[fidx] = False
            midx = cidx[~found]
            if midx.shape[0]:
                fp = bloom_false_positives(
                    keys[midx], self.index.bloom[level][cpos[~found]],
                    self.cfg.bloom_fpr)
                reads[midx] += fp.astype(np.int32)
        return seqs, reads, probed

    def _flat_level(self, level: int) -> tuple[np.ndarray, np.ndarray]:
        """The level's keys/seqs as one sorted flat array pair, cached
        against the LevelIndex mutation counter (deep levels mutate
        rarely, so rebuilds amortize to nearly nothing)."""
        ver = self.index.version[level]
        ent = self._flat.get(level)
        if ent is None or ent[0] != ver:
            lvl = self.levels[level]
            if lvl:
                fkeys = np.concatenate([s.keys for s in lvl])
                fseqs = np.concatenate([s.seqs for s in lvl])
            else:
                fkeys = np.empty(0, np.int64)
                fseqs = np.empty(0, np.int64)
            ent = (ver, fkeys, fseqs)
            self._flat[level] = ent
        return ent[1], ent[2]

    def _probe_sst_batch(self, sst: SST, bloom_seed: np.uint64,
                         idx: np.ndarray, keys: np.ndarray, seqs: np.ndarray,
                         reads: np.ndarray, probed: np.ndarray,
                         active: np.ndarray) -> None:
        """Probe one SST for the (in-range) ops at positions ``idx``.

        A found tombstone resolves the op as not-found (seq stays -1) but
        still costs the block read — the marker had to be fetched to learn
        the key is dead.
        """
        probed[idx] += 1
        k = keys[idx]
        pos = np.searchsorted(sst.keys, k)
        pos = np.minimum(pos, sst.n - 1)
        found = sst.keys[pos] == k
        fidx = idx[found]
        log, tomb = seq_decode(sst.seqs[pos[found]])
        seqs[fidx] = np.where(tomb, -1, log)
        reads[fidx] += 1     # bloom true positive -> one block read
        active[fidx] = False
        midx = idx[~found]
        if midx.shape[0]:
            fp = bloom_false_positives(keys[midx], bloom_seed,
                                       self.cfg.bloom_fpr)
            reads[midx] += fp.astype(np.int32)

    # --------------------------------------------------------------- scan
    def scan_batch(self, start_keys: np.ndarray,
                   lengths: np.ndarray) -> ResultBatch:
        """Vectorized forward range scans — thin wrapper over
        :meth:`apply_batch`.  Scan *i* returns up to ``lengths[i]`` live
        (non-deleted, latest-wins) keys ``>= start_keys[i]`` in sorted
        order; payloads land in the result's flattened scan arrays."""
        return self.apply_batch(RequestBatch.scans(start_keys, lengths))

    def _scan_impl(self, start_keys: np.ndarray, lengths: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray]:
        """Resolve a batch of scans: ``(counts, blocks, files, keys, seqs)``.

        Per level, ONE backend-routed :meth:`LevelIndex.scan_spans` query
        resolves every pending scan's SST span; each scan then k-way merges
        its per-source runs through ``merge_backend.merge_runs`` (numpy /
        jnp / the Pallas merge-path kernel) with latest-wins dedup, drops
        tombstones, and keeps the first ``lengths[i]`` live keys.

        Runs are gathered with a per-run entry cap ``m`` (starting at the
        requested length) and the cap doubles until the window is *proven*
        complete: every returned key must lie at or before the minimum
        frontier (last delivered key) of any capped run, else a newer
        version or tombstone beyond some cap could falsify the window.

        Device cost models a merging iterator, not the gather: every
        device run (each L0 SST, one per deeper level) is *seeked* (one
        file probe, at least one block), then charged
        ``ceil(consumed_bytes / block_size)`` blocks for the entries the
        heap actually consumed — those with key <= the window's last key —
        opening later SSTs of a level's span only when consumption crosses
        into them.
        """
        cfg = self.cfg
        kv = cfg.kv_size
        n = int(start_keys.shape[0])
        start_keys = np.ascontiguousarray(start_keys, np.int64)
        want = np.asarray(lengths, np.int64)
        counts = np.zeros(n, np.int64)
        blocks = np.zeros(n, np.int32)
        files = np.zeros(n, np.int32)
        out_k: list = [np.empty(0, np.int64)] * n
        out_s: list = [np.empty(0, np.int64)] * n
        if n == 0:
            return counts, blocks, files, np.empty(0, np.int64), \
                np.empty(0, np.int64)
        pending = np.arange(n)
        m = np.maximum(want, 1).copy()
        # Span byte budget: m keys plus one (max-size) partial leading SST.
        max_sst = cfg.s_M + cfg.s_m + kv
        while pending.size:
            spans = {}
            for level in range(1, cfg.max_levels):
                if self.index.n_ssts(level):
                    spans[level] = self.index.scan_spans(
                        level, start_keys[pending], m[pending] * kv + max_sst)
            still = []
            for j, op in enumerate(pending):
                op = int(op)
                op_spans = {lvl: (int(s[j]), int(e[j]))
                            for lvl, (s, e) in spans.items()}
                done = self._scan_one(op, int(start_keys[op]), int(want[op]),
                                      int(m[op]), op_spans, counts, blocks,
                                      files, out_k, out_s)
                if not done:
                    still.append(op)
            pending = np.asarray(still, np.int64)
            m[pending] *= 2
        flat_k = np.concatenate(out_k) if n else np.empty(0, np.int64)
        flat_s = np.concatenate(out_s) if n else np.empty(0, np.int64)
        return counts, blocks, files, flat_k, flat_s

    def _scan_one(self, op: int, k: int, want: int, m: int,
                  spans: dict[int, tuple[int, int]], counts, blocks, files,
                  out_k: list, out_s: list) -> bool:
        """One gather/merge round for scan ``op`` at run cap ``m``; returns
        False when the cap must double (window not yet provably complete)."""
        cfg = self.cfg
        kv = cfg.kv_size
        bsz = cfg.block_size
        runs: list[tuple[np.ndarray, np.ndarray]] = []
        frontiers: list[int] = []   # last delivered key of each capped run
        # Device runs for the iterator cost model: (keys, SST part bounds).
        dev_runs: list[tuple[np.ndarray, np.ndarray]] = []
        for mt in [self.memtable] + self.immutables:
            ks, ss, more = mt.scan_from(k, m)
            if more:
                frontiers.append(int(ks[-1]))
            if ks.shape[0]:
                runs.append((ks, ss))
        for sst in self.levels[0]:
            if sst.largest < k:
                continue
            ks, ss = sst.scan_from(k, m)
            if ks.shape[0] == 0:
                continue
            if ks.shape[0] == m and sst.largest > int(ks[-1]):
                frontiers.append(int(ks[-1]))
            runs.append((ks, ss))
            dev_runs.append((ks, np.asarray([ks.shape[0]], np.int64)))
        for level, (start, end) in spans.items():
            remaining = m
            parts_k: list[np.ndarray] = []
            parts_s: list[np.ndarray] = []
            for pos in range(start, end):
                if remaining <= 0:
                    break
                sst = self.levels[level][pos]
                if pos == start:
                    ks, ss = sst.scan_from(k, remaining)
                else:
                    ks, ss = sst.keys[:remaining], sst.seqs[:remaining]
                if ks.shape[0] == 0:
                    continue
                parts_k.append(ks)
                parts_s.append(ss)
                remaining -= int(ks.shape[0])
            if parts_k:
                lk = np.concatenate(parts_k)
                ls = np.concatenate(parts_s)
                if (lk.shape[0] == m
                        and int(self.index.largest[level][-1]) > int(lk[-1])):
                    frontiers.append(int(lk[-1]))
                runs.append((lk, ls))
                bounds = np.cumsum([p.shape[0] for p in parts_k])
                dev_runs.append((lk, bounds.astype(np.int64)))
        if not runs:
            return True          # nothing at or past k anywhere
        keys, seqs = merge_backend.merge_runs(runs)
        log, tomb = seq_decode(seqs)
        live_idx = np.nonzero(~tomb)[0]
        if frontiers:
            frontier = min(frontiers)
            trusted = live_idx[keys[live_idx] <= frontier]
            if trusted.shape[0] < want:
                return False     # double m: window not provably complete
        take = live_idx[:want]
        last_key = int(keys[take[-1]]) if take.shape[0] else None
        n_blocks = n_files = 0
        for rk, bounds in dev_runs:
            consumed = 0 if last_key is None else \
                int(np.searchsorted(rk, last_key, side="right"))
            if consumed == 0:
                n_files += 1     # seek only: position at the first entry
                n_blocks += 1
                continue
            prev = 0
            for b in bounds.tolist():
                part = min(consumed, b) - prev
                if part <= 0:
                    break
                n_files += 1
                n_blocks += -(-part * kv // bsz)
                prev = b
        out_k[op] = keys[take]
        out_s[op] = log[take]
        counts[op] = int(take.shape[0])
        blocks[op] = n_blocks
        files[op] = n_files
        return True

    # -------------------------------------------------------------- misc
    def level_sizes(self) -> list[int]:
        return [total_size(l) for l in self.levels]

    def total_keys(self) -> int:
        n = self.memtable.n + sum(m.n for m in self.immutables)
        return n + sum(s.n for lvl in self.levels for s in lvl)

    def check_invariants(self) -> None:
        """Mechanism invariants (index mirroring, SST sortedness, level
        disjointness) plus the strategy object's policy-specific ones."""
        from .sst import level_check_disjoint
        self.index.check_against(self.levels)
        for sst in self.levels[0]:
            sst.check_invariants()
        for level in range(1, self.cfg.max_levels):
            for sst in self.levels[level]:
                sst.check_invariants()
            level_check_disjoint(self.levels[level])
        self.policy.check_invariants(self)

    def merged_view(self) -> dict[int, int]:
        """Ground-truth *live* key -> latest logical seq, for tests.

        Encoded seqnos are monotone in the logical seq, so latest-wins is
        max-encoded-wins; keys whose winning entry is a DELETE tombstone
        are dropped from the user-visible view.
        """
        view: dict[int, int] = {}
        for level in range(self.cfg.max_levels - 1, 0, -1):
            for sst in self.levels[level]:
                for k, s in zip(sst.keys.tolist(), sst.seqs.tolist()):
                    prev = view.get(k)
                    if prev is None or s > prev:
                        view[k] = s
        for sst in self.levels[0]:
            for k, s in zip(sst.keys.tolist(), sst.seqs.tolist()):
                prev = view.get(k)
                if prev is None or s > prev:
                    view[k] = s
        for mt in self.immutables + [self.memtable]:
            ks, ss = mt.to_sorted()
            for k, s in zip(ks.tolist(), ss.tolist()):
                prev = view.get(k)
                if prev is None or s > prev:
                    view[k] = s
        return {k: s >> 1 for k, s in view.items() if not (s & 1)}

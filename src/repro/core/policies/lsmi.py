"""LSMi (paper Fig 3a): incremental compaction without L0 tiering and
fixed-size L1 SSTs — one L0 SST at a time, but every compaction rewrites
the whole overlap.

Chain shape: the incremental head keeps chains *narrow* (fan-in = 1 L0
SST + its L1 overlap), but without vLSM's phi/vSSTs the chains run long —
each pop cascades through more levels before the trigger clears."""

from __future__ import annotations

from ..types import LSMConfig
from .base import CompactionPolicy
from .registry import register


class LSMIPolicy(CompactionPolicy):
    name = "lsmi"
    tiering_l0 = False

    def default_config(self, scale: int = 1 << 20) -> LSMConfig:
        return LSMConfig(
            memtable_size=scale, sst_size=scale, l0_max_ssts=4,
            policy=self.name, debt_factor=0.0, growth_factor=8,
        )


register(LSMIPolicy())

"""Name -> CompactionPolicy registry (the policy resolution surface).

Benchmarks, the CLI (``benchmarks/run.py --policy``), tests, and the
mechanism itself resolve policies through :func:`get`; registering a new
policy makes it show up everywhere (CI smoke, db_bench rows, the
policy-invariance property test) with zero workflow edits.
"""

from __future__ import annotations

from .base import CompactionPolicy

_REGISTRY: dict[str, CompactionPolicy] = {}


def register(policy: CompactionPolicy) -> CompactionPolicy:
    """Register a policy instance under ``policy.name``; returns it."""
    if not policy.name:
        raise ValueError("policy must set a non-empty .name")
    if policy.name in _REGISTRY:
        raise ValueError(f"compaction policy {policy.name!r} is already "
                         f"registered (by {type(_REGISTRY[policy.name]).__name__})")
    _REGISTRY[policy.name] = policy
    return policy


def get(name) -> CompactionPolicy:
    """Resolve a policy by registry name (str, or anything carrying a
    ``.value`` name — the legacy ``Policy`` enum members do)."""
    key = getattr(name, "value", name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown compaction policy {key!r}; registered policies: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def names() -> list[str]:
    """Registered policy names, in registration (canonical bench) order."""
    return list(_REGISTRY)


def default_configs(scale: int = 1 << 20) -> dict:
    """``{name: policy.default_config(scale)}`` for every registered policy."""
    return {n: p.default_config(scale) for n, p in _REGISTRY.items()}


def resolve_names(spec: str) -> list[str]:
    """CLI policy-sweep resolution: ``"all"`` -> every registered name, else
    a comma-separated (whitespace-tolerant) list validated via :func:`get`."""
    if spec == "all":
        return names()
    return [get(p.strip()).name for p in spec.split(",")]

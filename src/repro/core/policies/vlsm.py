"""vLSM (paper Fig 3d): no L0 tiering, small SSTs, growth factor ``phi``
between L1 and L2, and overlap-aware vSSTs in L1 with good/poor selection
(§4.2)."""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from ..sst import SST
from ..types import LSMConfig
from ..vsst import plan_vssts, select_good_vssts
from .base import CompactionPolicy
from .registry import register

if TYPE_CHECKING:
    from ..lsm import Job, LSMTree


class VLSMPolicy(CompactionPolicy):
    name = "vlsm"
    tiering_l0 = False

    def default_config(self, scale: int = 1 << 20,
                       sst_frac: int = 8) -> LSMConfig:
        """vLSM §5 defaults: SSTs S_M = scale/sst_frac (8 MB when scale=64
        MB), memtable == S_M, L1 = f*S_M, phi = 32 between L1 and L2."""
        sst = max(1, scale // sst_frac)
        return LSMConfig(
            memtable_size=sst, sst_size=sst, l0_max_ssts=4,
            policy=self.name, debt_factor=0.0, growth_factor=8, phi=32,
        )

    def level_target(self, cfg: LSMConfig, level: int) -> int:
        if level < 1:
            return cfg.l0_max_ssts * cfg.memtable_size
        l1 = cfg.growth_factor * cfg.sst_size
        if level == 1:
            return l1
        l2 = cfg.phi * l1
        return l2 * cfg.growth_factor ** (level - 2)

    def build_l1_ssts(self, tree: "LSMTree", keys: np.ndarray,
                      seqs: np.ndarray) -> list[SST]:
        """Cut the merged L1 stream into overlap-aware vSSTs (§4.2)."""
        cfg = tree.cfg
        fence_lo, fence_hi = tree.index.fences(2)
        plans = plan_vssts(keys, cfg.kv_size, cfg.s_m, cfg.s_M,
                           cfg.growth_factor, fence_lo, fence_hi,
                           cfg.sst_size)
        tree.stats.overlap_probes += int(keys.shape[0])  # per-key look-ahead
        out: list[SST] = []
        for p in plans:
            sst = SST(keys[p.start:p.end], seqs[p.start:p.end], cfg.kv_size)
            out.append(sst)
            if p.good:
                tree.stats.vssts_good += 1
                tree.stats.vsst_good_bytes += sst.size
            else:
                tree.stats.vssts_poor += 1
                tree.stats.vsst_poor_bytes += sst.size
        return out

    def pick_compaction(self, tree: "LSMTree", level: int,
                        deps: list["Job"]) -> "Job | None":
        if level == 1:
            return self._vlsm_l1(tree, deps)
        return super().pick_compaction(tree, level, deps)

    def _vlsm_l1(self, tree: "LSMTree", deps: list["Job"]) -> "Job | None":
        """§4.2.2: compact a set of *good* vSSTs whose cumulative size
        frees room for the next L0 SST."""
        cfg = tree.cfg
        l1 = tree.levels[1]
        if not l1:
            return None
        fence_lo, fence_hi = tree.index.fences(2)
        # One batched overlap query scores every L1 vSST against L2.
        ov = tree.index.overlap_counts(2, *tree.index.fences(1))
        picked = select_good_vssts(l1, fence_lo, fence_hi, cfg.sst_size,
                                   cfg.growth_factor, cfg.sst_size, ov=ov)
        tree.stats.overlap_probes += len(l1)
        if not picked:
            # Φ too large: no good vSSTs exist (paper's Fig 13 failure mode).
            # Fall back to the least-bad vSST so the store still progresses.
            ratios = ov * cfg.sst_size / np.maximum(1, tree.index.sizes[1])
            picked = [int(np.argmin(ratios))]
        return tree.merge_down(1, picked, deps)

    def chain_priority(self, cfg: LSMConfig, head: "Job",
                       chain_jobs: list["Job"]):
        """vLSM chain urgency: L0-pressure chains first, and among equals
        the *narrowest* chain (fewest total bytes) first — with many small
        incremental chains in flight, clearing the cheapest L0 slot
        soonest is what keeps the write-stop gate open (§4.1's narrow
        chains are the asset; schedule them like one)."""
        tier = 0 if any(j.level == 0 for j in chain_jobs) else 1
        return (tier, sum(j.total_bytes for j in chain_jobs))

    def check_invariants(self, tree: "LSMTree") -> None:
        for sst in tree.levels[1]:
            # S_M plus the tail-absorption slack: a trailing fragment
            # smaller than S_m merges into its predecessor (§4.2), so a
            # vSST may legitimately reach S_M + S_m.
            assert sst.size <= tree.cfg.s_M + tree.cfg.s_m + tree.cfg.kv_size, \
                "vSST exceeds S_M + S_m tail slack"


register(VLSMPolicy())

"""RocksDB-family baselines (paper Fig 3b): tiering compaction in L0 —
when L0 fills, ALL L0 SSTs merge with ALL overlapping L1 SSTs (the wide
first chain stage) — then leveled min-overlap picks below.  ``rocksdb``
allows bounded compaction debt; ``rocksdb_io`` none (overflow disabled).

Chain shape (§3, the paper's tail-latency diagnosis): the tiering head
makes every flush-triggered chain *wide* — its fan-in is the whole of L0
plus the L1 overlap — so a stalled queue waits on a large, monolithic
merge.  Chain urgency stays the base default (L0-relieving chains before
background sweeps, RocksDB's own low-pri boost)."""

from __future__ import annotations

from ..types import LSMConfig
from .base import CompactionPolicy
from .registry import register


class RocksDBPolicy(CompactionPolicy):
    name = "rocksdb"
    tiering_l0 = True

    def default_config(self, scale: int = 1 << 20) -> LSMConfig:
        """RocksDB defaults at a byte ``scale`` standing in for 64 MB."""
        return LSMConfig(
            memtable_size=scale, sst_size=scale, l0_max_ssts=4,
            policy=self.name, debt_factor=0.25, growth_factor=8,
        )


class RocksDBIOPolicy(RocksDBPolicy):
    name = "rocksdb_io"

    def default_config(self, scale: int = 1 << 20) -> LSMConfig:
        return RocksDBPolicy.default_config(self, scale).with_(
            debt_factor=0.0)


register(RocksDBPolicy())
register(RocksDBIOPolicy())

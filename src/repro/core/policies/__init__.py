"""Registry-backed compaction policies (the policy/mechanism split).

``LSMTree`` and ``Simulator`` are policy-agnostic mechanism engines; every
compaction decision — L0 strategy, level pick/scoring, SST sizing, stall
and debt parameters, config defaults, policy invariants — lives in a
:class:`CompactionPolicy` object resolved by name::

    from repro.core.policies import get_policy, names, default_configs

    cfg = get_policy("lazy").default_config(scale=1 << 18)
    names()  # ['vlsm', 'rocksdb', 'rocksdb_io', 'adoc', 'lsmi', 'lazy']

Importing this package registers the six built-in policies (registration
order below is the canonical bench order).  Third-party policies register
with :func:`register` and immediately resolve everywhere by name.
"""

from .base import CompactionPolicy
from .registry import (default_configs, get, names, register,
                       resolve_names)

# Built-in policies self-register on import (canonical order: the paper's
# Fig 3 designs first, then the lazy-leveling proof-of-API policy).
from . import vlsm as _vlsm          # noqa: E402,F401
from . import rocksdb as _rocksdb    # noqa: E402,F401  (rocksdb, rocksdb_io)
from . import adoc as _adoc          # noqa: E402,F401
from . import lsmi as _lsmi          # noqa: E402,F401
from . import lazy as _lazy          # noqa: E402,F401

get_policy = get

__all__ = ["CompactionPolicy", "default_configs", "get", "get_policy",
           "names", "register", "resolve_names"]

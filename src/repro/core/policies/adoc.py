"""ADOC (paper Fig 3c): tiering L0 plus large compaction debt and batched
background compactions — the scheduling approach.  Levels intentionally run
*past* target (debt, §3.3) and only compact in big batches once they exceed
1.5x target: that is the mechanism by which ADOC trades I/O amplification
(larger overlaps while overfull) for fewer stalls.

Chain shape: the tiering head is as wide as RocksDB's, but the debt
batching shifts work into *background* chains (soft-limit sweeps) that the
chain-aware DES pool runs at lower urgency than L0 relief — ADOC's
scheduling idea expressed as chain priority."""

from __future__ import annotations

from ..types import LSMConfig
from .registry import register
from .rocksdb import RocksDBPolicy


class ADOCPolicy(RocksDBPolicy):
    name = "adoc"
    soft_limit_factor = 1.5

    def default_config(self, scale: int = 1 << 20) -> LSMConfig:
        return RocksDBPolicy.default_config(self, scale).with_(
            debt_factor=1.0, adoc_batch=4)

    def pick_batch(self, cfg: LSMConfig) -> int:
        return cfg.adoc_batch


register(ADOCPolicy())

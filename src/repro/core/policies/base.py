"""The abstract ``CompactionPolicy`` strategy interface.

The paper's core contribution is a *policy* (small SSTs, no L0 tiering,
large L1->L2 growth, overlap-aware L1 vSSTs) layered on an unchanged LSM
*mechanism*.  This module makes that split first-class: ``LSMTree`` owns
the mechanism (memtable, flush, splice, merge, LevelIndex, read paths) and
every compaction *decision* is delegated to a ``CompactionPolicy`` object
resolved by registry name (:mod:`repro.core.policies.registry`).

A policy owns:

* **L0 strategy** — :meth:`compact_l0`, built from the two shared bodies
  :meth:`_tiering_l0` (merge ALL of L0 with ALL overlapping L1, RocksDB
  family) and :meth:`_incremental_l0` (pop ONE FIFO L0 SST, vLSM/LSMi);
* **level pick & scoring** — :meth:`pick_compaction` (default: RocksDB's
  min overlap-ratio scheduler over the LevelIndex fence arrays);
* **SST sizing & build** — :meth:`build_l1_ssts` (default: fixed-size
  ``split_fixed``; vLSM overrides with overlap-aware vSST planning);
* **stall / debt parameters** — :attr:`soft_limit_factor`,
  :meth:`level_target` / :meth:`level_limit`, and the DES stall gates
  :meth:`l0_stop_ssts` / :meth:`write_buffer_limit`;
* **chain scheduling urgency** — :meth:`chain_priority`, the sort key the
  DES's chain-aware compaction pool orders drained chains by (vLSM and
  lazy override it; see ``docs/architecture.md``);
* **config defaults** — :meth:`default_config`, the policy's canned
  ``LSMConfig`` (what ``LSMConfig.rocksdb_default`` et al. delegate to);
* **policy-specific invariants** — :meth:`check_invariants`, run by the
  mechanism's own invariant sweep (continuously when
  ``cfg.paranoid_checks`` is on).

Writing a new policy means subclassing this, overriding the hooks that
differ, and calling ``registry.register(YourPolicy())`` — no edits to
``lsm.py`` / ``sim.py``.  ``repro.core.policies.lazy`` is the worked
example.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from ..sst import split_fixed, total_size
from ..types import LSMConfig

if TYPE_CHECKING:  # mechanism types, imported lazily to avoid a cycle
    from ..lsm import Job, LSMTree

#: The public mechanism surface: the only ``tree`` methods a policy may
#: call to mutate structure.  repro-lint (rules L103/L104) enforces this
#: statically and the generated contract table below renders it.
MECHANISM_PRIMITIVES = (
    "emit_compact_job",
    "merge_down",
    "merge_runs",
    "overlap",
    "replace_in_level",
    "strip_bottom_tombstones",
)
#: Read-only ``tree.index`` queries policies may use for scoring.
INDEX_QUERIES = (
    "check_against",
    "fences",
    "n_ssts",
    "overlap_bytes",
    "overlap_counts",
    "overlap_ranges",
    "overlap_slice",
    "scan_spans",
    "size_prefix",
)
#: ``tree.index`` mutators owned by the two shared L0 bodies — policies
#: never call these anywhere else.
L0_INDEX_MUTATORS = ("l0_clear", "l0_popleft")


class CompactionPolicy:
    """Strategy base class: every hook has the RocksDB-leveled default.

    Hook contract, common to all of them:

    * Hooks receive the live ``LSMTree`` (or its frozen ``LSMConfig``) —
      they may *read* anything, but must mutate structure **only**
      through the mechanism primitives (``tree.merge_down``,
      ``tree.merge_runs``, ``tree.overlap``, ``tree.replace_in_level``,
      ``tree.strip_bottom_tombstones``, ``tree.emit_compact_job``).
      Never touch ``tree.levels`` / ``tree.index`` except via L0
      ownership inside the two shared L0 bodies.
    * ``cfg`` is a frozen dataclass: never mutated, derive with
      ``cfg.with_(...)``.
    * Pure *parameter* hooks (``level_target``, ``l0_stop_ssts``, ...)
      must be deterministic functions of their inputs — the DES calls
      them repeatedly and assumes stable answers.

    .. contract-table-start

    Hook surface (generated; regenerate with ``python -m repro.analysis --write-contract-table``):

    default_config(scale, **kw)            [required]
    level_target(cfg, level)               [default provided]
    level_limit(cfg, level)                [default provided]
    l0_stop_ssts(cfg)                      [default provided]
    write_buffer_limit(cfg)                [default provided]
    chain_priority(cfg, head, chain_jobs)  [default provided]
    pick_batch(cfg)                        [default provided]
    incoming_bytes(tree, level)            [default provided]
    compact_l0(tree, deps)                 [default provided]
    pick_compaction(tree, level, deps)     [default provided]
    build_l1_ssts(tree, keys, seqs)        [default provided]
    check_invariants(tree)                 [default provided]
    _tiering_l0(tree, deps)                [shared L0 body]
    _incremental_l0(tree, deps)            [shared L0 body]

    mechanism primitives (the only tree mutators policies may call):
      emit_compact_job, merge_down, merge_runs, overlap, replace_in_level, strip_bottom_tombstones
    read-only index queries:
      check_against, fences, n_ssts, overlap_bytes, overlap_counts, overlap_ranges, overlap_slice, scan_spans, size_prefix
    index mutators owned by the shared L0 bodies:
      l0_clear, l0_popleft

    .. contract-table-end
    """

    #: registry key; also the value carried in ``LSMConfig.policy``
    name: str = ""
    #: does L0 use a tiering (merge-all) compaction step?
    tiering_l0: bool = False
    #: background compactions fire once a level exceeds
    #: ``soft_limit_factor * level_target`` (ADOC's debt batching uses 1.5)
    soft_limit_factor: float = 1.0

    # ------------------------------------------------------ configuration
    def default_config(self, scale: int = 1 << 20, **kw) -> LSMConfig:
        """The policy's canned ``LSMConfig`` at a byte ``scale`` standing
        in for the paper's 64 MB.

        Contract: must return a config whose ``policy`` field round-trips
        (``cfg.policy == self.name``) so registry resolution is stable.
        Required override — the base class has no sensible default shape.
        """
        raise NotImplementedError

    def level_target(self, cfg: LSMConfig, level: int) -> int:
        """Target size in bytes for ``level`` (the L0 target is the
        trigger occupancy in bytes).

        Inputs: the frozen config and a level index ``0 <= level <
        cfg.max_levels``.  Must be pure (no tree access — targets are
        queried before trees exist).  Default: L1 sized like L0, then
        geometric ``growth_factor`` scaling."""
        if level < 1:
            return cfg.l0_max_ssts * cfg.memtable_size
        l1 = cfg.l0_max_ssts * cfg.memtable_size
        return l1 * cfg.growth_factor ** (level - 1)

    def level_limit(self, cfg: LSMConfig, level: int) -> int:
        """Hard size limit for ``level`` including compaction debt
        (overflow): the room-making recursion compacts a level before
        letting incoming bytes push it past this.  Default:
        ``level_target * (1 + cfg.debt_factor)``."""
        return int(self.level_target(cfg, level) * (1.0 + cfg.debt_factor))

    # --------------------------------------------------- DES stall gates
    def l0_stop_ssts(self, cfg: LSMConfig) -> int:
        """Temporal L0 occupancy (file count) at which the DES
        write-stops the foreground queue (RocksDB's level0_stop gate).
        Pure function of the config.  Default: ``cfg.l0_stop_ssts``."""
        return cfg.l0_stop_ssts

    def write_buffer_limit(self, cfg: LSMConfig) -> int:
        """Write buffers (active + immutable) a region may hold before a
        fill stalls on the in-flight flush (RocksDB's
        max_write_buffer_number).  Default: ``cfg.max_write_buffers``."""
        return cfg.max_write_buffers

    # ---------------------------------------------------- DES scheduling
    def chain_priority(self, cfg: LSMConfig, head: "Job",
                       chain_jobs: list["Job"]):
        """Urgency sort key for one compaction *chain* in the DES's
        chain-aware compaction pool (``ChainScheduler``).

        Inputs: the frozen config, the chain ``head`` (the job that
        relieves the trigger — the L0 stage of a flush-triggered chain),
        and the chain's jobs in emission order (deepest stage first,
        head last).  Returns any sortable key; **lower schedules
        earlier**, ties keep FIFO emission order.  Must not mutate the
        jobs — scheduling has not happened yet (``t_start``/``t_finish``
        are unset).

        Default (RocksDB low-pri semantics): chains containing an
        L0-source stage outrank background soft-limit sweeps."""
        return (0 if any(j.level == 0 for j in chain_jobs) else 1, 0)

    # ------------------------------------------------ structural strategy
    def pick_batch(self, cfg: LSMConfig) -> int:
        """SSTs picked per L1+ compaction job (ADOC batches several).
        Pure function of the config; must be >= 1.  Default: 1."""
        return 1

    def incoming_bytes(self, tree: "LSMTree", level: int) -> int:
        """Bytes one compaction from ``level`` pushes into ``level + 1`` —
        what the chain's room-making recursion must clear below.
        Read-only on the tree.  Default: the whole of L0 for tiering
        designs, one SST otherwise."""
        cfg = tree.cfg
        if level == 0:
            if self.tiering_l0:
                return total_size(tree.levels[0])
            return tree.levels[0][0].size if tree.levels[0] else cfg.sst_size
        return cfg.sst_size

    def compact_l0(self, tree: "LSMTree", deps: list["Job"]) -> "Job | None":
        """One L0 compaction pass (called when L0 is at its trigger).

        ``deps`` is the chain's dependency tail (the deeper job this
        stage must follow) and must be forwarded verbatim to
        ``emit_compact_job`` so chain lineage stays intact.  Returns the
        emitted head job, or ``None`` when there is nothing to do.
        Default: dispatch to the shared tiering/incremental body per
        :attr:`tiering_l0`."""
        if self.tiering_l0:
            return self._tiering_l0(tree, deps)
        return self._incremental_l0(tree, deps)

    def pick_compaction(self, tree: "LSMTree", level: int,
                        deps: list["Job"]) -> "Job | None":
        """Compact from ``level >= 1`` into ``level + 1``.

        Same ``deps`` forwarding contract as :meth:`compact_l0`; all
        mutation must go through ``tree.merge_down`` (or the other
        primitives).  Default: RocksDB's scheduler — the min
        overlap-ratio SST(s) first, scored with one batched LevelIndex
        fence query."""
        if not tree.levels[level]:
            return None
        scores = (tree.index.overlap_bytes(level, level + 1)
                  / np.maximum(1, tree.index.sizes[level]))
        order = np.lexsort((np.arange(scores.shape[0]), scores))
        picked = [int(i) for i in order[:self.pick_batch(tree.cfg)]]
        return tree.merge_down(level, picked, deps)

    def build_l1_ssts(self, tree: "LSMTree", keys: np.ndarray,
                      seqs: np.ndarray) -> list:
        """Cut an L0->L1 merged stream into L1 SSTs (the sizing hook).

        ``keys``/``seqs`` are the merged, tombstone-stripped stream; the
        hook must partition them into SSTs **without reordering or
        dropping entries** (the caller splices the result into L1 and
        accounts the bytes).  May read ``tree.index`` fences (vLSM scores
        L2 overlap) but must not mutate the tree.  Default: fixed-size
        ``split_fixed`` cuts; vLSM builds overlap-aware vSSTs."""
        cfg = tree.cfg
        return split_fixed(keys, seqs, cfg.kv_size, cfg.sst_size)

    def check_invariants(self, tree: "LSMTree") -> None:
        """Policy-specific structural invariants, run by the mechanism's
        own sweep after its sortedness/disjointness/index/chain checks —
        continuously when ``cfg.paranoid_checks`` is on.  Read-only;
        raise ``AssertionError`` on violation.  Default: none."""

    # ------------------------------------- shared L0 strategy bodies
    def _tiering_l0(self, tree: "LSMTree", deps: list["Job"]) -> "Job | None":
        """RocksDB-family: merge ALL of L0 with ALL overlapping L1."""
        l0 = tree.levels[0]
        if not l0:
            return None
        lo = int(tree.index.smallest[0].min())
        hi = int(tree.index.largest[0].max())
        l1_over = tree.overlap(1, lo, hi)
        runs = [(s.keys, s.seqs) for s in reversed(l0)]  # newest first
        runs += [(s.keys, s.seqs) for s in l1_over]
        keys, seqs = tree.merge_runs(runs)
        keys, seqs = tree.strip_bottom_tombstones(1, keys, seqs)
        new = self.build_l1_ssts(tree, keys, seqs)
        tree.replace_in_level(1, l1_over, new)
        read_b = total_size(l0) + total_size(l1_over)
        write_b = sum(s.size for s in new)
        n_l0 = len(l0)
        tree.levels[0] = []
        tree.index.l0_clear()
        job = tree.emit_compact_job(0, read_b, write_b,
                                    n_l0 + len(l1_over), len(new), deps)
        job.l0_consumed = n_l0
        return job

    def _incremental_l0(self, tree: "LSMTree",
                        deps: list["Job"]) -> "Job | None":
        """vLSM / LSMi: pick ONE L0 SST (FIFO) and merge into L1, building
        the outputs through :meth:`build_l1_ssts`."""
        l0 = tree.levels[0]
        if not l0:
            return None
        src = l0.pop(0)  # FIFO: oldest first (vLSM §4.1)
        tree.index.l0_popleft()
        l1_over = tree.overlap(1, src.smallest, src.largest)
        runs = [(src.keys, src.seqs)] + [(s.keys, s.seqs) for s in l1_over]
        keys, seqs = tree.merge_runs(runs)
        keys, seqs = tree.strip_bottom_tombstones(1, keys, seqs)
        new = self.build_l1_ssts(tree, keys, seqs)
        tree.replace_in_level(1, l1_over, new)
        read_b = src.size + total_size(l1_over)
        write_b = sum(s.size for s in new)
        job = tree.emit_compact_job(0, read_b, write_b,
                                    1 + len(l1_over), len(new), deps)
        job.l0_consumed = 1
        return job

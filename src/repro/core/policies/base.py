"""The abstract ``CompactionPolicy`` strategy interface.

The paper's core contribution is a *policy* (small SSTs, no L0 tiering,
large L1->L2 growth, overlap-aware L1 vSSTs) layered on an unchanged LSM
*mechanism*.  This module makes that split first-class: ``LSMTree`` owns
the mechanism (memtable, flush, splice, merge, LevelIndex, read paths) and
every compaction *decision* is delegated to a ``CompactionPolicy`` object
resolved by registry name (:mod:`repro.core.policies.registry`).

A policy owns:

* **L0 strategy** — :meth:`compact_l0`, built from the two shared bodies
  :meth:`_tiering_l0` (merge ALL of L0 with ALL overlapping L1, RocksDB
  family) and :meth:`_incremental_l0` (pop ONE FIFO L0 SST, vLSM/LSMi);
* **level pick & scoring** — :meth:`pick_compaction` (default: RocksDB's
  min overlap-ratio scheduler over the LevelIndex fence arrays);
* **SST sizing & build** — :meth:`build_l1_ssts` (default: fixed-size
  ``split_fixed``; vLSM overrides with overlap-aware vSST planning);
* **stall / debt parameters** — :attr:`soft_limit_factor`,
  :meth:`level_target` / :meth:`level_limit`, and the DES stall gates
  :meth:`l0_stop_ssts` / :meth:`write_buffer_limit`;
* **config defaults** — :meth:`default_config`, the policy's canned
  ``LSMConfig`` (what ``LSMConfig.rocksdb_default`` et al. delegate to);
* **policy-specific invariants** — :meth:`check_invariants`, run by the
  mechanism's own invariant sweep (continuously when
  ``cfg.paranoid_checks`` is on).

Writing a new policy means subclassing this, overriding the hooks that
differ, and calling ``registry.register(YourPolicy())`` — no edits to
``lsm.py`` / ``sim.py``.  ``repro.core.policies.lazy`` is the worked
example.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from ..sst import split_fixed, total_size
from ..types import LSMConfig

if TYPE_CHECKING:  # mechanism types, imported lazily to avoid a cycle
    from ..lsm import Job, LSMTree


class CompactionPolicy:
    """Strategy base class: every hook has the RocksDB-leveled default."""

    #: registry key; also the value carried in ``LSMConfig.policy``
    name: str = ""
    #: does L0 use a tiering (merge-all) compaction step?
    tiering_l0: bool = False
    #: background compactions fire once a level exceeds
    #: ``soft_limit_factor * level_target`` (ADOC's debt batching uses 1.5)
    soft_limit_factor: float = 1.0

    # ------------------------------------------------------ configuration
    def default_config(self, scale: int = 1 << 20, **kw) -> LSMConfig:
        """The policy's canned ``LSMConfig`` at a byte ``scale`` standing
        in for the paper's 64 MB."""
        raise NotImplementedError

    def level_target(self, cfg: LSMConfig, level: int) -> int:
        """Target size in bytes for ``level`` (L0 target is the trigger
        occupancy).  Default: L1 sized like L0, then geometric growth."""
        if level < 1:
            return cfg.l0_max_ssts * cfg.memtable_size
        l1 = cfg.l0_max_ssts * cfg.memtable_size
        return l1 * cfg.growth_factor ** (level - 1)

    def level_limit(self, cfg: LSMConfig, level: int) -> int:
        """Hard limit including compaction debt (overflow)."""
        return int(self.level_target(cfg, level) * (1.0 + cfg.debt_factor))

    # --------------------------------------------------- DES stall gates
    def l0_stop_ssts(self, cfg: LSMConfig) -> int:
        """Temporal L0 occupancy at which the DES write-stops the queue."""
        return cfg.l0_stop_ssts

    def write_buffer_limit(self, cfg: LSMConfig) -> int:
        """Write buffers (active + immutable) before a write-buffer stall."""
        return cfg.max_write_buffers

    # ------------------------------------------------ structural strategy
    def pick_batch(self, cfg: LSMConfig) -> int:
        """SSTs picked per L1+ compaction job (ADOC batches several)."""
        return 1

    def incoming_bytes(self, tree: "LSMTree", level: int) -> int:
        """Bytes one compaction from ``level`` pushes into ``level + 1`` —
        what the chain's room-making recursion must clear below."""
        cfg = tree.cfg
        if level == 0:
            if self.tiering_l0:
                return total_size(tree.levels[0])
            return tree.levels[0][0].size if tree.levels[0] else cfg.sst_size
        return cfg.sst_size

    def compact_l0(self, tree: "LSMTree", deps: list["Job"]) -> "Job | None":
        """One L0 compaction pass (L0 is at its trigger)."""
        if self.tiering_l0:
            return self._tiering_l0(tree, deps)
        return self._incremental_l0(tree, deps)

    def pick_compaction(self, tree: "LSMTree", level: int,
                        deps: list["Job"]) -> "Job | None":
        """Compact from ``level >= 1`` into ``level + 1``.  Default:
        RocksDB's scheduler — min overlap-ratio SST(s) first, scored with
        one batched LevelIndex fence query."""
        if not tree.levels[level]:
            return None
        scores = (tree.index.overlap_bytes(level, level + 1)
                  / np.maximum(1, tree.index.sizes[level]))
        order = np.lexsort((np.arange(scores.shape[0]), scores))
        picked = [int(i) for i in order[:self.pick_batch(tree.cfg)]]
        return tree.merge_down(level, picked, deps)

    def build_l1_ssts(self, tree: "LSMTree", keys: np.ndarray,
                      seqs: np.ndarray) -> list:
        """Cut an L0->L1 merged stream into L1 SSTs (the sizing hook).
        Default: fixed-size SSTs; vLSM builds overlap-aware vSSTs."""
        cfg = tree.cfg
        return split_fixed(keys, seqs, cfg.kv_size, cfg.sst_size)

    def check_invariants(self, tree: "LSMTree") -> None:
        """Policy-specific structural invariants (on top of the mechanism's
        sortedness/disjointness/index checks).  Default: none."""

    # ------------------------------------- shared L0 strategy bodies
    def _tiering_l0(self, tree: "LSMTree", deps: list["Job"]) -> "Job | None":
        """RocksDB-family: merge ALL of L0 with ALL overlapping L1."""
        l0 = tree.levels[0]
        if not l0:
            return None
        lo = int(tree.index.smallest[0].min())
        hi = int(tree.index.largest[0].max())
        l1_over = tree.overlap(1, lo, hi)
        runs = [(s.keys, s.seqs) for s in reversed(l0)]  # newest first
        runs += [(s.keys, s.seqs) for s in l1_over]
        keys, seqs = tree.merge_runs(runs)
        keys, seqs = tree.strip_bottom_tombstones(1, keys, seqs)
        new = self.build_l1_ssts(tree, keys, seqs)
        tree.replace_in_level(1, l1_over, new)
        read_b = total_size(l0) + total_size(l1_over)
        write_b = sum(s.size for s in new)
        n_l0 = len(l0)
        tree.levels[0] = []
        tree.index.l0_clear()
        job = tree.emit_compact_job(0, read_b, write_b,
                                    n_l0 + len(l1_over), len(new), deps)
        job.l0_consumed = n_l0
        return job

    def _incremental_l0(self, tree: "LSMTree",
                        deps: list["Job"]) -> "Job | None":
        """vLSM / LSMi: pick ONE L0 SST (FIFO) and merge into L1, building
        the outputs through :meth:`build_l1_ssts`."""
        l0 = tree.levels[0]
        if not l0:
            return None
        src = l0.pop(0)  # FIFO: oldest first (vLSM §4.1)
        tree.index.l0_popleft()
        l1_over = tree.overlap(1, src.smallest, src.largest)
        runs = [(src.keys, src.seqs)] + [(s.keys, s.seqs) for s in l1_over]
        keys, seqs = tree.merge_runs(runs)
        keys, seqs = tree.strip_bottom_tombstones(1, keys, seqs)
        new = self.build_l1_ssts(tree, keys, seqs)
        tree.replace_in_level(1, l1_over, new)
        read_b = src.size + total_size(l1_over)
        write_b = sum(s.size for s in new)
        job = tree.emit_compact_job(0, read_b, write_b,
                                    1 + len(l1_over), len(new), deps)
        job.l0_consumed = 1
        return job

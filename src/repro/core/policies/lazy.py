"""Lazy leveling (Dostoevsky-style), the registry's proof-of-API policy.

Dostoevsky's *lazy leveling* (Dayan & Idreos, 2018) merges greedily only
at the largest level — which dominates space and read cost — and merges
*lazily* everywhere above it, trading intermediate-level write
amplification for bounded point-read and space overheads.

Mapping onto this engine's mechanism (levels >= 1 stay sorted and
pairwise disjoint, so a disjoint full level IS one sorted run):

* **L0**: tiering — accumulate the trigger count, then one wide merge of
  ALL L0 SSTs into L1 (lazy at the top).
* **Intermediate levels** (1 .. max-3): no per-SST scheduling.  A full
  level moves *wholesale* into the next one as a single wide compaction —
  the disjoint-level expression of moving a tiered run down.  Combined
  with a generous debt factor, compactions here are rare and wide.
* **Bottom transition** (level max-2 -> the last level): the default
  leveled min-overlap pick, one SST at a time — greedy at the bottom, so
  the largest level keeps leveled read/space behaviour.

The policy is implemented purely against the public mechanism interface
(``tree.merge_down`` / ``tree.overlap`` / the LevelIndex fence arrays):
zero edits to ``lsm.py`` — that is the point of the registry.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from ..sst import total_size
from ..types import LSMConfig
from .base import CompactionPolicy
from .registry import register

if TYPE_CHECKING:
    from ..lsm import Job, LSMTree


class LazyLevelingPolicy(CompactionPolicy):
    name = "lazy"
    tiering_l0 = True
    # lazy: let intermediate levels run a bit past target before the
    # background sweep moves them wholesale.
    soft_limit_factor = 1.25

    def default_config(self, scale: int = 1 << 20) -> LSMConfig:
        return LSMConfig(
            memtable_size=scale, sst_size=scale, l0_max_ssts=4,
            policy=self.name, debt_factor=0.5, growth_factor=8,
        )

    def incoming_bytes(self, tree: "LSMTree", level: int) -> int:
        cfg = tree.cfg
        if 1 <= level < cfg.max_levels - 2:
            # a wholesale move pushes the whole level down at once
            return max(cfg.sst_size, total_size(tree.levels[level]))
        return super().incoming_bytes(tree, level)

    def pick_compaction(self, tree: "LSMTree", level: int,
                        deps: list["Job"]) -> "Job | None":
        lvl = tree.levels[level]
        if not lvl:
            return None
        if level < tree.cfg.max_levels - 2:
            # lazy: the full (disjoint == single-run) level moves wholesale
            return tree.merge_down(level, list(range(len(lvl))), deps)
        # greedy at the bottom: leveled min-overlap single-SST pick
        return super().pick_compaction(tree, level, deps)

    def chain_priority(self, cfg: LSMConfig, head: "Job",
                       chain_jobs: list["Job"]):
        """Lazy chain urgency: L0 relief first, bottom-level greedy picks
        next, and the wholesale intermediate moves — the *lazy* work this
        policy exists to defer — last.  They are huge and nothing
        foreground waits on them, so they soak up whatever slot time the
        urgent chains leave."""
        if any(j.level == 0 for j in chain_jobs):
            return (0, 0)
        wholesale = 1 <= head.level < cfg.max_levels - 2
        return (2, 0) if wholesale else (1, 0)

    def check_invariants(self, tree: "LSMTree") -> None:
        # all on-device SSTs are fixed-size cuts: never beyond S_M (+1 key)
        cfg = tree.cfg
        for level in range(1, cfg.max_levels):
            for sst in tree.levels[level]:
                assert sst.size <= cfg.sst_size + cfg.kv_size, \
                    "lazy-leveling SST exceeds the fixed S_M cut"


register(LazyLevelingPolicy())

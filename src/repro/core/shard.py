"""Keyspace sharding: ``ShardRouter`` + ``ShardedStore`` over N LSM trees.

Every real large-scale LSM deployment partitions the keyspace over many
independent LSM instances ("shards") that contend for one storage device
— the standard scaling axis the partitioned/multi-instance organizations
in the LSM survey literature describe.  This module supplies the two
pieces the rest of the repo builds on:

* :class:`ShardRouter` — a vectorized key -> shard partition function.
  ``"hash"`` mixes the key through a splitmix64 finalizer (load spreads
  evenly, ranges scatter across shards); ``"range"`` stripes the key
  domain ``[0, shard_key_space)`` into contiguous shards (scan-friendly,
  skew-prone).  Routing is columnar: one numpy pass per batch.

* :class:`ShardedStore` — N per-shard :class:`~repro.core.lsm.LSMTree`
  instances behind the same typed :class:`~repro.core.types.RequestBatch`
  entry point as a bare tree.  A batch is split into one sub-batch per
  shard (PUT/GET/DELETE route to exactly one shard; SCAN fans out to
  every shard and the per-shard windows are k-way merged), applied, and
  the per-op results are re-gathered **in arrival order**, so callers
  cannot tell how many shards sit behind the store — except through the
  per-shard stats.  With ``n_shards=1`` the store is byte-identical to a
  bare ``LSMTree`` (the property tests in ``tests/test_shard.py`` pin
  merged_view / GET / SCAN / chain-ledger parity across all registered
  policies).

Time does not live here: the DES (:mod:`repro.core.sim`) drives the
shards' fills/flushes itself through per-shard foreground queues over a
*shared* device.  ``ShardedStore`` is the structural container plus the
standalone (harness-free) store API.
"""

from __future__ import annotations

import numpy as np

from .lsm import Job, LSMTree
from .stats import FleetStats, Stats
from .types import LSMConfig, OpKind, RequestBatch, ResultBatch


def hash_keys(keys: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over int64 keys -> uint64 mix.

    The standard 64-bit avalanche (shift-xor / odd-constant multiply
    rounds): adjacent keys land on unrelated shards, so range-local load
    cannot pile onto one shard under the hash router.
    """
    x = np.asarray(keys, np.int64).astype(np.uint64)
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


class ShardRouter:
    """The keyspace partition function: ``shard_of(keys) -> shard ids``.

    Deterministic, vectorized, and a *partition*: every key maps to
    exactly one shard in ``[0, n_shards)`` (property-tested).
    """

    def __init__(self, n_shards: int, kind: str = "hash",
                 key_space: int = 1 << 48):
        assert n_shards >= 1
        assert kind in ("hash", "range"), f"unknown router kind {kind!r}"
        self.n_shards = int(n_shards)
        self.kind = kind
        self.key_space = int(key_space)
        # range stripe width, rounded up so stripe*n covers the domain
        self._stripe = max(1, -(-self.key_space // self.n_shards))

    @staticmethod
    def from_config(cfg: LSMConfig) -> "ShardRouter":
        return ShardRouter(cfg.n_shards, cfg.shard_router,
                           cfg.shard_key_space)

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Shard id (int64) for each key — one columnar pass."""
        keys = np.asarray(keys, np.int64)
        if self.n_shards == 1:
            return np.zeros(keys.shape[0], np.int64)
        if self.kind == "hash":
            return (hash_keys(keys) % np.uint64(self.n_shards)) \
                .astype(np.int64)
        # range: contiguous stripes; keys outside the declared domain
        # clamp to the edge shards instead of wrapping
        return np.clip(keys // self._stripe, 0, self.n_shards - 1)


class ShardedStore:
    """N per-shard LSM trees behind one typed batch entry point.

    Each shard owns its own :class:`~repro.core.stats.Stats` ledger (the
    per-shard observability the fleet report aggregates); ``self.stats``
    is shard 0's ledger when ``n_shards == 1`` (bare-tree parity) and a
    read-only :class:`~repro.core.stats.FleetStats` aggregate otherwise.

    Maintenance (seal/flush/compaction-trigger) is explicit — the DES
    owns *when* those happen; standalone users call
    :meth:`seal_full_memtables` (or :meth:`flush_shard`) between batches,
    mirroring how a bare ``LSMTree`` is driven.
    """

    def __init__(self, cfg: LSMConfig,
                 shard_stats: list[Stats] | None = None):
        self.cfg = cfg
        self.n_shards = cfg.n_shards
        self.router = ShardRouter.from_config(cfg)
        if shard_stats is None:
            shard_stats = [Stats() for _ in range(self.n_shards)]
        assert len(shard_stats) == self.n_shards
        self.shard_stats = shard_stats
        self.shards = [LSMTree(cfg, st, shard_id=s)
                       for s, st in enumerate(shard_stats)]
        self.stats: Stats | FleetStats = shard_stats[0] \
            if self.n_shards == 1 else FleetStats(shard_stats)
        # Background jobs drained by the store's own memtable rolls (a
        # standalone store has no clock — the DES never ingests through
        # here, so jobs are a structural record for callers/tests).
        self.job_log: list[Job] = []

    # --------------------------------------------------- typed entry point
    def apply_batch(self, batch: RequestBatch) -> ResultBatch:
        """Route one typed batch to the shards and re-gather the results.

        Vectorized columnar routing: ``router.shard_of(keys)`` in one
        pass, then one sub-batch per touched shard.  PUT/DELETE ops go to
        exactly their key's shard (chunked at the shard memtable's
        capacity, rolling full memtables through flush, exactly as a
        harness seals a bare tree on its fill events); GETs go to their
        key's shard; SCAN ops fan out to **every** shard (a range crosses
        hash shards arbitrarily) and the per-shard windows — disjoint by
        the partition property — are merged by key, keeping the first
        ``scan_lens[i]`` live keys.  Writes land first, then the batch's
        reads observe post-write state (the ``LSMTree.apply_batch``
        contract, fleet-wide).  Results land back at their op's arrival
        position, so the gather is order-preserving by construction.
        """
        n = len(batch)
        kinds = batch.kinds
        shard_ids = self.router.shard_of(batch.keys)
        seqs_out = np.full(n, -1, np.int64)
        reads = np.zeros(n, np.int32)
        probed = np.zeros(n, np.int32)
        offsets = np.zeros(n + 1, np.int64)
        is_write = batch.mask(OpKind.PUT, OpKind.DELETE)
        is_get = batch.mask(OpKind.GET)
        is_scan = batch.mask(OpKind.SCAN)
        # 1. writes, per shard, in arrival order within the shard
        for s in range(self.n_shards):
            widx = np.nonzero(is_write & (shard_ids == s))[0]
            if widx.shape[0] == 0:
                continue
            assigned = self._ingest(s, batch.keys[widx],
                                    kinds[widx] == OpKind.DELETE)
            seqs_out[widx] = assigned
            batch.seqnos[widx] = assigned
        # 2. point reads, per shard
        for s in range(self.n_shards):
            gidx = np.nonzero(is_get & (shard_ids == s))[0]
            if gidx.shape[0] == 0:
                continue
            res = self.shards[s].apply_batch(
                RequestBatch.gets(batch.keys[gidx]))
            seqs_out[gidx] = res.seqs
            reads[gidx] = res.reads
            probed[gidx] = res.probed
        # 3. scans fan out to every shard; merge the disjoint windows
        out_k: list[np.ndarray] = [np.empty(0, np.int64)] * n
        out_s: list[np.ndarray] = [np.empty(0, np.int64)] * n
        if is_scan.any():
            sidx = np.nonzero(is_scan)[0]
            for s in range(self.n_shards):
                res = self.shards[s].apply_batch(RequestBatch.scans(
                    batch.keys[sidx], batch.scan_lens[sidx]))
                for p, g in enumerate(sidx.tolist()):
                    ks, ss = res.scan_slice(p)
                    if ks.shape[0]:
                        out_k[g] = np.concatenate([out_k[g], ks])
                        out_s[g] = np.concatenate([out_s[g], ss])
                    reads[g] += int(res.reads[p])
                    probed[g] += int(res.probed[p])
            for g in sidx.tolist():
                # shards partition the keyspace -> windows are disjoint;
                # merge = sort by key, keep the first `want` live keys
                order = np.argsort(out_k[g], kind="stable")
                take = order[:int(batch.scan_lens[g])]
                out_k[g] = out_k[g][take]
                out_s[g] = out_s[g][take]
                seqs_out[g] = int(take.shape[0])
            lens = np.zeros(n, np.int64)
            lens[sidx] = [out_k[int(g)].shape[0] for g in sidx]
            np.cumsum(lens, out=offsets[1:])
            scan_keys = np.concatenate(out_k)
            scan_seqs = np.concatenate(out_s)
        else:
            scan_keys = scan_seqs = np.empty(0, np.int64)
        return ResultBatch(kinds, seqs_out, reads, probed, offsets,
                           scan_keys, scan_seqs)

    def _ingest(self, shard: int, keys: np.ndarray,
                tombs: np.ndarray) -> np.ndarray:
        """Write keys/tombstones into one shard, chunked at the memtable's
        capacity; a memtable that fills rolls immediately (seal -> flush
        -> background triggers), mirroring a harness's fill events."""
        tree = self.shards[shard]
        n = int(keys.shape[0])
        seqs = np.empty(n, np.int64)
        i = 0
        while i < n:
            if tree.memtable.room == 0:
                self._roll_memtable(shard)
            take = min(tree.memtable.room, n - i)
            seqs[i:i + take] = tree._write_batch(keys[i:i + take],
                                                 tombs[i:i + take])
            i += take
            if tree.memtable.full:
                self._roll_memtable(shard)
        return seqs

    def _roll_memtable(self, shard: int) -> None:
        tree = self.shards[shard]
        tree.seal_memtable()
        tree.flush_immutable()
        tree.background_triggers()
        self.job_log.extend(tree.drain_jobs())

    # ------------------------------------------------------- thin wrappers
    def put_batch(self, keys: np.ndarray) -> np.ndarray:
        return self.apply_batch(RequestBatch.puts(keys)).seqs

    def delete_batch(self, keys: np.ndarray) -> np.ndarray:
        return self.apply_batch(RequestBatch.deletes(keys)).seqs

    def get_batch(self, keys: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        res = self.apply_batch(RequestBatch.gets(keys))
        return res.seqs, res.reads, res.probed

    def scan_batch(self, start_keys: np.ndarray,
                   lengths: np.ndarray) -> ResultBatch:
        return self.apply_batch(RequestBatch.scans(start_keys, lengths))

    # -------------------------------------------------------- maintenance
    def seal_full_memtables(self) -> list[Job]:
        """Standalone maintenance: seal + flush every shard whose active
        memtable is full (the cadence a harness-free caller drives between
        batches, mirroring how a bare tree is sealed when full); returns
        the drained background jobs of all shards, shard order."""
        jobs: list[Job] = []
        for s, tree in enumerate(self.shards):
            if tree.memtable.full:
                jobs.extend(self.flush_shard(s))
        return jobs

    def flush_shard(self, shard: int) -> list[Job]:
        """Seal/flush one shard's active memtable (even part-full) and run
        its background triggers; returns the drained jobs."""
        tree = self.shards[shard]
        if tree.memtable.n == 0 and not tree.immutables:
            return []
        if tree.memtable.n > 0:
            tree.seal_memtable()
        while tree.immutables:
            tree.flush_immutable()
        tree.background_triggers()
        return tree.drain_jobs()

    def drain_jobs(self) -> list[Job]:
        out: list[Job] = []
        for tree in self.shards:
            out.extend(tree.drain_jobs())
        return out

    # -------------------------------------------------------------- misc
    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        return self.router.shard_of(keys)

    def total_keys(self) -> int:
        return sum(t.total_keys() for t in self.shards)

    def level_sizes(self) -> list[list[int]]:
        """Per-shard level byte sizes (shard-major)."""
        return [t.level_sizes() for t in self.shards]

    def merged_view(self) -> dict[int, int]:
        """Union of the shards' live views — disjoint by the partition."""
        view: dict[int, int] = {}
        for t in self.shards:
            view.update(t.merged_view())
        return view

    def check_invariants(self) -> None:
        for t in self.shards:
            t.check_invariants()

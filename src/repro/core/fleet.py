"""Device-vectorized fleet engine: batched Lindley / fill-event simulation.

:class:`repro.core.sim.Simulator` advances every shard's processed clock
inside its event heap: each staged fill event runs the shard's next op
window through the store (``apply_batch``) *and* folds the window into the
Lindley recursion, so structural replay, clock arithmetic and slot
scheduling are interleaved in one Python loop.  That loop is exact but
serial — a policy × config × shard-count × arrival-rate sweep pays it
once per matrix point.

This module splits the engine around two observations:

1. A window's effect on the clock is fully captured by two scalars.  With
   ``S`` the window's service prefix-sum and ``a`` its arrivals,

       D' = wsum + max(D, wmax),   wsum = S[-1],  wmax = max_k(a_k - S[k-1])

   for ANY carried-in clock ``D`` (associativity of the max-plus scan).
2. The structural evolution of a tree is **arrival-independent**: windows
   are op-index-defined (every ``keys_per_memtable``-th write), stall
   injection only ever touches the last op of an already-aggregated
   window, and SST/bloom identity is engine-order-independent (per-tree
   uid allocators, ``repro.core.sst.uid_allocator``).  The same op stream
   therefore produces byte-identical trees, read counters and base
   service under every arrival schedule.

Hence the engine runs in phases:

* :meth:`FleetEngine.prepare_structural` — replay each tree's windows in
  shard order: all ``apply_batch`` / flush / compaction-emission work,
  the expensive part — recording per window the service prefix
  (``shifted``), the total ``wsum`` and the drained job batches.  Paid
  ONCE per op stream.
* :meth:`FleetEngine.temporal_pass` — for one arrival schedule, derive
  every window's ``wmax`` with a single ``np.maximum.reduceat`` (exact:
  max is associative) and run the *same* event heap as the serial engine
  — write-buffer/L0 stall gates, chain-aware slot scheduling, stall
  injection — with every clock advance O(1) from the recorded
  aggregates.  Repeatable: a whole arrival-rate axis reuses one
  structural replay.
* **Final latency** is one batched Lindley program over every pending
  shard queue: :func:`repro.kernels.lindley_scan.ops.lindley_batch_np`
  pads the ragged queues to ``[B, n_pad]`` and evaluates either the
  vmapped jnp oracle or the Pallas blocked-scan kernel.
  :func:`fleet_sweep` stacks the queues of EVERY (point, rate, shard)
  into that single batch, so the device sees the whole matrix as one
  program.

The serial engine stays untouched as the correctness oracle:
``tests/test_fleet.py`` asserts per-op latency parity across policies,
shard counts and arrival rates.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

import numpy as np

from .lsm import Job
from .sim import ChainScheduler, SimResult, Simulator, SlotPool
from .types import DeviceModel, LSMConfig


@dataclass
class _ShardPlan:
    """Phase-A record for one shard: everything its temporal passes need
    to advance the clock without re-touching the store."""

    starts: np.ndarray   # window start offsets into the shard's op order
    wsum: np.ndarray     # per-window total service (float64)
    shifted: np.ndarray  # per-op within-window service prefix S_{k-1}
    tail: int            # shard-local index where the trailing (clockless)
                         # window begins == end of the last fill window
    pos_tail: np.ndarray  # shard_pos[:tail] (the window-covered op indices)


@dataclass
class PendingRun:
    """One temporal pass awaiting its final batched Lindley evaluation.

    Snapshots the pass-local ledgers (several passes share one engine):
    ``queues`` are the per-shard ``(service, arrivals)`` pairs to scan,
    and ``stall_events`` / ``job_log`` feed the :class:`SimResult` that
    :meth:`FleetEngine.finalize` assembles from the departure times.
    """

    queues: list[tuple[np.ndarray, np.ndarray]]
    arrivals: np.ndarray
    stall_events: list[tuple[int, float]] = field(default_factory=list)
    job_log: list[Job] = field(default_factory=list)
    # per-shard chain-ledger snapshot at pass end (the shared Stats only
    # keep the most recent pass's temporal fields)
    chain_counts: list[int] = field(default_factory=list)
    chain_stall_s: list[float] = field(default_factory=list)


class FleetEngine(Simulator):
    """Two-phase (structural replay + O(1)-advance temporal heap) engine.

    Drop-in for :class:`Simulator`: same constructor, same :meth:`run`
    contract, same :class:`SimResult`.  The phase boundary is public —
    ``prepare_structural`` once, ``temporal_pass`` per arrival schedule,
    ``finalize`` per pass — so :func:`fleet_sweep` can amortize the
    structural replay over a rate axis and batch every pending Lindley
    pass into one device program.

    Caveat: the engine owns ONE set of :class:`~repro.core.stats.Stats`
    ledgers.  Structural counters (I/O amp, chain shapes, vSST quality)
    are arrival-independent and valid for every pass; the chain ledger's
    *temporal* fields (``t_start``/``t_finish``/``stall_s``) are reset
    each :meth:`temporal_pass` and therefore reflect the **most recent**
    pass only.
    """

    def prepare_structural(self, op_types: np.ndarray, keys: np.ndarray,
                           scan_lens: np.ndarray | None = None) -> None:
        """Phase A: replay every shard's op windows through the store and
        record the per-window Lindley aggregates + drained job batches."""
        n = op_types.shape[0]
        st = self._setup(op_types, keys, np.zeros(n, np.float64), scan_lens)
        self._st = st
        self._plans: list[_ShardPlan] = []
        # batches[s][k]: shard s's k-th fill event's drained job batches
        # (post-flush drain, then post-background-trigger drain), each
        # pre-ranked for slot assignment — durations and chain-priority
        # order are pure functions of the jobs, so they are computed HERE
        # once instead of inside every temporal pass.
        self._batches: list[list[list[tuple]]] = []
        for s in range(self.n_shards):
            pos = st.shard_pos[s]
            m = pos.shape[0]
            n_ev = len(st.ev_by_shard[s])
            starts = np.empty(n_ev, np.int64)
            wsums = np.empty(n_ev, np.float64)
            shifted = np.zeros(m, np.float64)
            b: list[list[tuple]] = []
            cur = 0
            for k, (op_i, ti) in enumerate(st.ev_by_shard[s]):
                upper = int(np.searchsorted(pos, op_i, side="right"))
                idx = pos[cur:upper]
                self._apply_window(s, idx, st.op_types, st.keys,
                                   st.scan_lens, st.regions, st.get_reads,
                                   st.get_probed, st.service, st.block_t)
                svc = st.service[idx].astype(np.float64)
                s_cum = np.cumsum(svc)
                shifted[cur] = 0.0
                shifted[cur + 1:upper] = s_cum[:-1]
                starts[k] = cur
                wsums[k] = s_cum[-1]
                cur = upper
                tree = self.trees[ti]
                tree.seal_memtable()
                tree.flush_immutable()
                first = tree.drain_jobs()
                second = tree.drain_jobs() \
                    if tree.background_triggers() else []
                plans = [self._plan_batch(first)]
                if second:
                    plans.append(self._plan_batch(second))
                b.append(plans)
            if cur < m:
                # trailing window past the last fill event: structural
                # effects (read service) only, no clock consumer
                self._apply_window(s, pos[cur:], st.op_types, st.keys,
                                   st.scan_lens, st.regions, st.get_reads,
                                   st.get_probed, st.service, st.block_t)
            self._plans.append(_ShardPlan(starts, wsums, shifted, cur,
                                          pos[:cur]))
            self._batches.append(b)
        # Base per-op service after structural replay (device reads
        # charged, no stalls, no busy inflation): the reset point every
        # temporal pass starts from.
        self._service0 = st.service.copy()
        # Pass-scratch service buffer: temporal passes rewind into this
        # (fresh first-touch allocations are the dominant per-pass cost
        # on big matrices; only the gathered per-shard queues escape).
        self._svc_buf = np.empty_like(self._service0)

    def _plan_batch(self, drained: list[Job]) -> tuple:
        """Precompute the arrival-independent half of ``_schedule_jobs``
        for one drained batch: per-job durations, the chain-priority slot
        order (``ChainScheduler.rank_batch`` — pure in the jobs), and the
        flush/L0 bookkeeping flags.  Temporal passes replay the plan."""
        compacts = [(j, self._job_duration(j)) for j in drained
                    if j.kind == "compact"]
        if self.cfg.chain_aware_sched:
            ranked = ChainScheduler.rank_batch(compacts, self._chain_key)
        else:
            ranked = compacts              # legacy FIFO drain order
        flushes = [(j, self._job_duration(j), j.bytes_written > 0)
                   for j in drained if j.kind == "flush"]
        return ranked, [j for j, _ in compacts], flushes

    def _schedule_planned(self, plan: tuple, tree_idx: int,
                          t: float) -> None:
        """``_schedule_jobs`` with the structural half precomputed: slot
        assignment, L0 consumption and the ledgers — identical ordering
        and timestamps to the serial engine's path."""
        ranked, compacts, flushes = plan
        if compacts:
            self.compact_pool.schedule_seq(ranked, t, tree_idx)
            log = self.job_log
            for job in compacts:           # emission order, like drain
                if job.level == 0 and job.l0_consumed:
                    self._consume_l0(tree_idx, job.l0_consumed,
                                     job.t_finish, job.chain_id)
                self._note_scheduled(job)
                log.append(job)
        for job, dur, lands_sst in flushes:
            self.flush_pool.schedule(job, t, dur, tree_idx)
            self.flush_inflight[tree_idx].append(job.t_finish)
            if lands_sst:
                self.l0_entries[tree_idx].append([job.t_finish, np.inf, -1])
            self.job_log.append(job)

    def temporal_pass(self, arrivals: np.ndarray) -> PendingRun:
        """Phase B for one arrival schedule: the serial engine's event
        heap — identical ordering, stall gates and slot scheduling — with
        O(1) clock advances from the phase-A aggregates.  Returns the
        pass's pending shard queues + ledgers; call repeatedly with
        different schedules to sweep a rate axis over one replay."""
        st = self._st
        arrivals = np.asarray(arrivals, np.float64)
        assert arrivals.shape[0] == st.n
        st.arrivals = arrivals
        np.copyto(self._svc_buf, self._service0)
        st.service = service = self._svc_buf
        # pass-local temporal state (device pools, L0 occupancy, ledgers)
        n_trees = self.n_shards * self.n_regions
        self.l0_entries = [[] for _ in range(n_trees)]
        self.flush_inflight = [[] for _ in range(n_trees)]
        if self.sanitizer is not None:
            self.sanitizer.reset()    # each pass is its own timeline
        self.flush_pool = SlotPool(1, sanitizer=self.sanitizer)
        self.compact_pool = ChainScheduler(
            max(1, self.device.compaction_slots - 1),
            sanitizer=self.sanitizer)
        self.job_log = []
        self.stall_events = []
        for stats in self.shard_stats:
            for rec in stats.chains:
                rec.t_start = math.inf
                rec.t_finish = 0.0
                rec.stall_s = 0.0

        # Every window's wmax for THIS schedule, one reduceat per shard.
        # Exact: the reduction is a plain max over the same
        # ``a_k - S_{k-1}`` values the serial engine maxes per window.
        wmaxes: list[np.ndarray] = []
        for s in range(self.n_shards):
            plan = self._plans[s]
            if plan.starts.size:
                gaps = arrivals[plan.pos_tail] - plan.shifted[:plan.tail]
                wmaxes.append(np.maximum.reduceat(gaps, plan.starts))
            else:
                wmaxes.append(np.empty(0, np.float64))

        # Identical event ordering and stall/scheduling logic to
        # Simulator.run; the only difference is that _advance_clock's
        # structural work already happened, leaving wsum/wmax lookups.
        D = [0.0] * self.n_shards
        ptrs = [0] * self.n_shards
        heap: list[tuple[float, int, int, int]] = []

        def stage(s: int) -> None:
            k = ptrs[s]
            if k >= len(st.ev_by_shard[s]):
                return
            op_i, ti = st.ev_by_shard[s][k]
            D[s] = float(self._plans[s].wsum[k]) \
                + max(D[s], float(wmaxes[s][k]))
            heapq.heappush(heap, (D[s], op_i, s, ti))

        for s in range(self.n_shards):
            stage(s)
        while heap:
            t, op_i, s, ti = heapq.heappop(heap)
            if self.sanitizer is not None:
                self.sanitizer.on_event(ti, t)
            stall = self._wb_stall(ti, t)
            for plan in self._batches[s][ptrs[s]]:
                self._schedule_planned(plan, ti, t)
            l0_stall, cid = self._l0_stall(ti, t)
            if l0_stall > stall and cid >= 0:
                rec = self.shard_stats[s].chain_index.get(cid)
                if rec is not None:
                    rec.stall_s += l0_stall
            stall = max(stall, l0_stall)
            if stall > 0:
                service[op_i] += stall
                D[s] += stall
                self.stall_events.append((op_i, stall))
            ptrs[s] += 1
            stage(s)

        self._busy_inflation(st)
        pending = PendingRun(
            queues=[(service[p], arrivals[p]) for p in st.shard_pos],
            arrivals=arrivals,
            stall_events=self.stall_events,
            job_log=self.job_log,
            chain_counts=[len(s.chains) for s in self.shard_stats],
            chain_stall_s=[sum(c.stall_s for c in s.chains)
                           for s in self.shard_stats])
        self._pending = pending
        return pending

    def run_prepare(self, op_types: np.ndarray, keys: np.ndarray,
                    arrivals: np.ndarray,
                    scan_lens: np.ndarray | None = None
                    ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Phases A and B for a single schedule; returns the per-shard
        ``(service, arrivals)`` queues awaiting their Lindley pass."""
        self.prepare_structural(op_types, keys, scan_lens)
        return self.temporal_pass(arrivals).queues

    def finalize(self, departures: list[np.ndarray],
                 pending: PendingRun | None = None) -> SimResult:
        """Assemble the :class:`SimResult` from per-shard departure times
        (one array per queue of ``pending``; defaults to the most recent
        temporal pass)."""
        if pending is None:
            pending = self._pending
        st = self._st
        # np.empty is safe: shard_pos partitions every op, so each index
        # is written exactly once; queues already hold the gathered
        # per-shard arrivals, saving a second gather here.
        latency = np.empty(st.n, np.float64)
        makespan = 0.0
        for pos, (_svc, arr_q), dep in zip(st.shard_pos, pending.queues,
                                           departures):
            if pos.shape[0] == 0:
                continue
            latency[pos] = dep - arr_q
            makespan = max(makespan, float(dep[-1]))
        return self._make_result(st, latency, makespan,
                                 stall_events=pending.stall_events,
                                 job_log=pending.job_log,
                                 arrivals=pending.arrivals,
                                 chain_counts=pending.chain_counts,
                                 chain_stall_s=pending.chain_stall_s)

    def run(self, op_types: np.ndarray, keys: np.ndarray,
            arrivals: np.ndarray, scan_lens: np.ndarray | None = None,
            backend: str = "jnp") -> SimResult:
        """Full two-phase run.  ``backend`` picks the batched Lindley
        implementation: ``"jnp"`` (vmapped oracle — the CPU default) or
        ``"pallas"`` (the blocked-scan TPU kernel; interpret-mode here)."""
        from repro.kernels.lindley_scan.ops import lindley_batch_np
        queues = self.run_prepare(op_types, keys, arrivals, scan_lens)
        deps = lindley_batch_np([q[0] for q in queues],
                                [q[1] for q in queues], backend=backend)
        return self.finalize(deps)


def traffic_curve(eng: "FleetEngine", op_types: np.ndarray,
                  keys: np.ndarray, scan_lens: np.ndarray | None,
                  arrival_grid: list[np.ndarray],
                  backend: str = "numpy") -> list[SimResult]:
    """An offered-load axis over ONE structural replay.

    The serving layer's load curves scale every tenant's rate by a
    common factor, which compresses the arrival schedule but leaves the
    op stream (and hence store structure) invariant — exactly the
    amortization the two-phase split buys: phase A once, one cheap
    temporal pass + Lindley finalize per factor.  ``eng`` must be
    freshly constructed (callers pair this with ``reset_uid_counters``);
    per-pass results share its Stats like ``fleet_sweep`` points do.
    """
    from repro.kernels.lindley_scan.ops import lindley_batch_np
    eng.prepare_structural(op_types, keys, scan_lens)
    out: list[SimResult] = []
    for arr in arrival_grid:
        pd = eng.temporal_pass(arr)
        deps = lindley_batch_np([q[0] for q in pd.queues],
                                [q[1] for q in pd.queues], backend=backend)
        out.append(eng.finalize(deps, pending=pd))
    return out


# ---------------------------------------------------------------- sweeps
def reset_uid_counters() -> None:
    """Rewind the module-level SST/job/chain uid counters.

    Slot-0 trees draw SST uids from the shared module counter (seed
    compatibility), and uids seed bloom filters — so two engines over the
    same op stream are byte-identical only when they start from the same
    counter state.  The sweep drivers call this before constructing each
    engine; parity tests use the same idiom.  Safe globally: uids only
    need to be unique within one store.
    """
    from . import lsm as _lsm
    from . import sst as _sst
    _sst._ids = itertools.count()
    _lsm._job_ids = itertools.count()
    _lsm._chain_ids = itertools.count()


@dataclass
class SweepPoint:
    """One matrix point: a store configuration plus the op stream to
    drive it with.  ``label`` tags the result rows (e.g. "vlsm/4").
    Supply either one ``arrivals`` schedule or an ``arrivals_grid`` —
    a whole rate axis evaluated over a single structural replay.
    """

    label: str
    cfg: LSMConfig
    device: DeviceModel
    op_types: np.ndarray
    keys: np.ndarray
    arrivals: np.ndarray | None = None
    scan_lens: np.ndarray | None = None
    n_regions: int = 1
    arrivals_grid: list[np.ndarray] | None = None

    @property
    def grid(self) -> list[np.ndarray]:
        if self.arrivals_grid is not None:
            return self.arrivals_grid
        assert self.arrivals is not None, \
            f"SweepPoint {self.label!r} needs arrivals or arrivals_grid"
        return [self.arrivals]


def fleet_sweep(points: list[SweepPoint],
                backend: str = "jnp") -> list[list[SimResult]]:
    """Evaluate a policy × config × shard × rate matrix as one program.

    Each point gets its own :class:`FleetEngine` (independent store
    state) and ONE structural replay; each schedule in its ``grid`` is a
    cheap temporal pass over that replay.  On the device tiers
    ("jnp"/"pallas") every pending shard queue of every (point, rate) is
    then stacked into a single ``lindley_batch_np`` call — the whole
    matrix's latency accounting is one padded ``[B, n_pad]`` scan on the
    device.  The "numpy" CPU tier scans per queue regardless, so it
    streams Lindley + finalize per pass instead (same results; freed
    pass buffers recycle rather than first-touching the whole matrix's
    transient arrays at once).

    Returns one ``list[SimResult]`` per point, aligned with its grid.
    Per-point, the results share the engine's Stats: structural counters
    hold for every rate, chain *temporal* fields reflect the last pass.
    """
    from repro.kernels.lindley_scan.ops import lindley_batch_np
    if backend == "numpy":
        # CPU tier: the numpy backend loops queues anyway, so stream the
        # Lindley + finalize per pass instead of holding every pending
        # queue of the whole matrix alive — freed pass buffers get
        # recycled by the allocator, where the all-at-once layout pays
        # first-touch page faults for gigabytes of transient arrays.
        out: list[list[SimResult]] = []
        for p in points:
            reset_uid_counters()
            eng = FleetEngine(p.cfg, p.device, n_regions=p.n_regions)
            eng.prepare_structural(p.op_types, p.keys, p.scan_lens)
            rows: list[SimResult] = []
            for arr in p.grid:
                pd = eng.temporal_pass(arr)
                deps = lindley_batch_np([q[0] for q in pd.queues],
                                        [q[1] for q in pd.queues],
                                        backend="numpy")
                rows.append(eng.finalize(deps, pending=pd))
            out.append(rows)
        return out
    engines: list[FleetEngine] = []
    pendings: list[list[PendingRun]] = []
    spans: list[list[tuple[int, int]]] = []
    services: list[np.ndarray] = []
    arrival_qs: list[np.ndarray] = []
    for p in points:
        reset_uid_counters()
        eng = FleetEngine(p.cfg, p.device, n_regions=p.n_regions)
        eng.prepare_structural(p.op_types, p.keys, p.scan_lens)
        pds: list[PendingRun] = []
        sps: list[tuple[int, int]] = []
        for arr in p.grid:
            pd = eng.temporal_pass(arr)
            sps.append((len(services), len(services) + len(pd.queues)))
            services.extend(q[0] for q in pd.queues)
            arrival_qs.extend(q[1] for q in pd.queues)
            pds.append(pd)
        engines.append(eng)
        pendings.append(pds)
        spans.append(sps)
    deps = lindley_batch_np(services, arrival_qs, backend=backend)
    return [[eng.finalize(deps[a:b], pending=pd)
             for pd, (a, b) in zip(pds, sps)]
            for eng, pds, sps in zip(engines, pendings, spans)]


def serial_sweep(points: list[SweepPoint]) -> list[list[SimResult]]:
    """Heap-loop oracle over the same matrix: one serial
    :class:`Simulator` run per (point, rate) — the full structural replay
    every time.  The parity baseline for :func:`fleet_sweep` and the
    denominator of its reported speedup."""
    out: list[list[SimResult]] = []
    for p in points:
        rows: list[SimResult] = []
        for arr in p.grid:
            reset_uid_counters()
            sim = Simulator(p.cfg, p.device, n_regions=p.n_regions)
            rows.append(sim.run(p.op_types, p.keys, arr, p.scan_lens))
        out.append(rows)
    return out

"""Write buffer (memtable).

PUTs append into growing chunks; at flush time the buffer is sorted with a
stable argsort and deduplicated latest-wins — equivalent to a skiplist
memtable's iterator, but vectorized.  GETs scan the unsorted tail (the sim
issues GETs against full store state; memtable probes are modeled as free
CPU work, as in the paper's cost model where memtable hits never touch the
device).
"""

from __future__ import annotations

import numpy as np

from .sst import SST


class Memtable:
    def __init__(self, capacity_bytes: int, kv_size: int):
        self.capacity = capacity_bytes
        self.kv_size = kv_size
        self._keys: list[np.ndarray] = []
        self._seqs: list[np.ndarray] = []
        self._n = 0
        self._sorted: tuple[np.ndarray, np.ndarray] | None = None

    @property
    def n(self) -> int:
        return self._n

    @property
    def size(self) -> int:
        return self._n * self.kv_size

    @property
    def full(self) -> bool:
        return self.size >= self.capacity

    @property
    def room(self) -> int:
        """Number of puts that fit before the memtable is full."""
        return max(0, (self.capacity - self.size) // self.kv_size)

    def put_batch(self, keys: np.ndarray, seqs: np.ndarray) -> None:
        assert keys.shape == seqs.shape
        self._keys.append(np.asarray(keys, dtype=np.int64))
        self._seqs.append(np.asarray(seqs, dtype=np.int64))
        self._n += int(keys.shape[0])
        self._sorted = None

    def get(self, key: int) -> int | None:
        best = None
        for k, s in zip(self._keys, self._seqs):
            hits = np.nonzero(k == key)[0]
            if hits.size:
                cand = int(s[hits].max())
                best = cand if best is None else max(best, cand)
        return best

    def to_sorted(self) -> tuple[np.ndarray, np.ndarray]:
        """Sorted, latest-wins-deduplicated contents (cached until the next
        put; callers must not mutate the returned arrays)."""
        if self._sorted is not None:
            return self._sorted
        keys = np.concatenate(self._keys) if self._keys else np.empty(0, np.int64)
        seqs = np.concatenate(self._seqs) if self._seqs else np.empty(0, np.int64)
        if keys.size == 0:
            self._sorted = (keys, seqs)
            return self._sorted
        # Stable sort on key keeps insertion order among equal keys; take the
        # last occurrence of each key (highest seq, since seqs increase).
        order = np.argsort(keys, kind="stable")
        keys, seqs = keys[order], seqs[order]
        last = np.ones(keys.shape[0], dtype=bool)
        last[:-1] = keys[1:] != keys[:-1]
        self._sorted = (keys[last], seqs[last])
        return self._sorted

    def scan_from(self, key: int, m: int) -> tuple[np.ndarray, np.ndarray, bool]:
        """First ``m`` entries with key >= ``key`` (sorted, deduped) plus a
        flag saying whether more remain past the cap."""
        ks, ss = self.to_sorted()
        i = int(np.searchsorted(ks, key))
        return ks[i:i + m], ss[i:i + m], (ks.shape[0] - i) > m

    def get_batch(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`get` over many keys; -1 marks a miss."""
        out = np.full(keys.shape[0], -1, np.int64)
        sk, ss = self.to_sorted()
        if sk.shape[0] == 0:
            return out
        pos = np.searchsorted(sk, keys)
        pos = np.minimum(pos, sk.shape[0] - 1)
        hit = sk[pos] == keys
        out[hit] = ss[pos[hit]]
        return out

    def to_sst(self) -> SST:
        keys, seqs = self.to_sorted()
        return SST(keys, seqs, self.kv_size)

"""Explicit uid namespaces: per-engine SST/job/chain id streams.

Three module-global ``itertools.count`` streams historically numbered
every SST, job and chain in the process (``sst._ids``, ``lsm._job_ids``,
``lsm._chain_ids``).  Those uids are not cosmetic: SST uids seed the
bloom false-positive hash, so two engines replaying the same op stream
are byte-identical only when their uid streams match.  The sweep
drivers handled that with ``reset_uid_counters()`` before every engine
construction — correct for one engine at a time, but impossible to keep
correct once engines coexist (a cached structural replay held alive
next to a fresh engine, or sweep points running in parallel workers):
whoever allocates next perturbs everyone else's stream.

:class:`UidNamespace` makes the stream an explicit constructor argument:
``Simulator(cfg, device, uids=UidNamespace())`` draws every slot-0 SST
uid, job uid and chain id from ITS OWN counters, starting from zero —
exactly the state ``reset_uid_counters()`` rewinds the module counters
to, so a fresh namespace is byte-identical to the reset idiom while
being immune to any other engine's allocations.  Non-zero fleet slots
keep their per-tree disjoint counters (``slot << 40`` bases) either
way; they were never shared.

``reset_uid_counters`` (in :mod:`repro.core.fleet`) remains for callers
that construct engines without a namespace.
"""

from __future__ import annotations

import itertools


class UidNamespace:
    """One engine's private uid streams (SST / job / chain counters).

    A fresh namespace starts all three streams at zero — the same state
    ``reset_uid_counters()`` leaves the module-global counters in, which
    is what makes namespace-built engines byte-identical to the legacy
    reset-then-construct idiom (pinned in ``tests/test_sweeps.py``).
    """

    __slots__ = ("sst_ids", "job_ids", "chain_ids")

    def __init__(self) -> None:
        self.sst_ids = itertools.count()
        self.job_ids = itertools.count()
        self.chain_ids = itertools.count()

    def __reduce__(self):
        # itertools.count pickles fine, but a namespace crossing a
        # process boundary (fork-pool task args) should start fresh:
        # the receiving engine replays from op 0 either way.
        return (UidNamespace, ())

"""Overlap-aware vSST splitting (paper §4.2) and good-vSST selection (§4.2.2).

During an L0→L1 compaction the merged key stream must be cut into variable
size SSTs (vSSTs).  The look-ahead policy tracks, while a vSST grows, its
overlap ``O`` — the **number of fixed-size L2 SSTs its key range
intersects** — against the growth factor ``f``:

* a vSST must reach at least ``S_m = S_M / f`` bytes;
* at ``S_m``, if ``O > f`` the vSST is closed immediately — a **poor** vSST
  (it absorbed a high-overlap key range, shielding its neighbours);
* otherwise keys keep being appended while ``O <= f`` until either the next
  key would push ``O`` past ``f`` or the size reaches ``S_M`` — a **good**
  vSST.

Calibration against the paper's own numbers (Fig 13b): with Φ=32 (8 MB
SSTs) an ``S_m``-sized vSST spans ~4 L2 SSTs ≤ f=8, so ~90% of vSSTs end up
good; with Φ=64 (4 MB SSTs) an ``S_m`` vSST spans exactly 8 L2 SSTs — right
at the boundary — and jitter pushes ~94% past f, the paper's reported
failure mode.  A byte-ratio criterion cannot reproduce those numbers (it
would classify essentially everything poor at Φ=32), so the count-based
reading is the faithful one; the *ranking* used at selection time (§4.2.2)
is the byte ratio ``overlap_bytes / vsst_size``, as the paper states.

The per-key overlap probe is the CPU hot-spot the paper measures (§6.3
"check for every KV pair the overlap with the next-level SSTs").  Here it is
batched: overlap counts come from fence-pointer binary searches over the L2
boundaries (``np.searchsorted`` — the TPU counterpart is
``repro.kernels.overlap_scan``), and the walk advances fence-segment by
fence-segment instead of key by key, which is exact because the overlap
count is constant between fence crossings.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .sst import SST


@dataclass
class VSSTPlan:
    """A planned cut: keys[start:end] with its measured L2 overlap."""

    start: int
    end: int                # exclusive
    overlap_ssts: int       # number of L2 SSTs the range intersects
    good: bool

    def size(self, kv_size: int) -> int:
        return (self.end - self.start) * kv_size


def l2_fences(l2_ssts: list[SST]) -> tuple[np.ndarray, np.ndarray]:
    """(smallest, largest) arrays of a sorted, disjoint L2."""
    if not l2_ssts:
        z = np.empty(0, np.int64)
        return z, z
    lo = np.fromiter((s.smallest for s in l2_ssts), np.int64, len(l2_ssts))
    hi = np.fromiter((s.largest for s in l2_ssts), np.int64, len(l2_ssts))
    return lo, hi


def overlap_count_range(fence_lo: np.ndarray, fence_hi: np.ndarray,
                        key_lo: int, key_hi: int) -> int:
    """Number of L2 SSTs whose key range intersects [key_lo, key_hi]."""
    if fence_lo.size == 0:
        return 0
    first = int(np.searchsorted(fence_hi, key_lo, side="left"))
    last = int(np.searchsorted(fence_lo, key_hi, side="right"))
    return max(0, last - first)


def plan_vssts(keys: np.ndarray, kv_size: int, s_m: int, s_M: int, f: int,
               fence_lo: np.ndarray, fence_hi: np.ndarray,
               sst_size_l2: int) -> list[VSSTPlan]:
    """Cut a merged sorted key stream into vSST plans per the §4.2 heuristic.

    Closed form of the segment walk in :func:`plan_vssts_ref` (kept as the
    property-test oracle).  Two batched fence ranks over the whole stream —
    ``R[j] = #{fence_lo <= keys[j]}`` and ``Lh[i] = #{fence_hi < keys[i]}``
    — give the overlap of any cut as ``max(0, R[j-1] - Lh[i])``.  ``R`` is
    nondecreasing in ``j``, so the §4.2 "extend while overlap <= f" rule is
    one searchsorted per plan: the largest ``j`` with ``R[j-1] <= Lh[i]+f``.
    """
    del sst_size_l2  # good/poor is count-based; byte size only matters at selection
    n = int(keys.shape[0])
    if n == 0:
        return []
    min_keys = max(1, s_m // kv_size)
    max_keys = max(min_keys, s_M // kv_size)

    if fence_lo.size:
        r_arr = np.searchsorted(fence_lo, keys, side="right")
        lh_arr = np.searchsorted(fence_hi, keys, side="left")
    else:
        r_arr = np.zeros(n, np.int64)
        lh_arr = np.zeros(n, np.int64)

    def _ov(i: int, j: int) -> int:
        # L2 SSTs intersected by [keys[i], keys[j-1]]
        return max(0, int(r_arr[j - 1]) - int(lh_arr[i]))

    plans: list[VSSTPlan] = []
    i = 0
    while i < n:
        hard_end = min(n, i + max_keys)
        j_min = min(n, i + min_keys)
        ov_min = _ov(i, j_min)
        if ov_min > f:
            # Poor vSST: close at S_m (paper: "their size is always S_m").
            plans.append(VSSTPlan(i, j_min, ov_min, good=False))
            i = j_min
            continue
        # Good vSST: crossing-by-crossing replay of the segment walk over
        # the precomputed ranks (O(1) per crossing instead of fresh fence
        # searches).  The walk absorbs the remainder of the fence segment
        # containing j before re-checking f — a crossing sitting exactly
        # at j slips in unchecked, and such plans come out marked poor —
        # then stops at the first checked crossing whose R exceeds
        # ``Lh[i] + f``.
        j = j_min
        while j < hard_end:
            j = min(hard_end,
                    int(np.searchsorted(r_arr, r_arr[j], side="right")))
            if j >= hard_end or int(r_arr[j]) - int(lh_arr[i]) > f:
                break
            j += 1
        ov = _ov(i, j)
        plans.append(VSSTPlan(i, j, ov, good=ov <= f))
        i = j
    # Absorb a too-small trailing plan into its predecessor.
    if len(plans) >= 2 and (plans[-1].end - plans[-1].start) < min_keys:
        tail = plans.pop()
        prev = plans.pop()
        ov = _ov(prev.start, tail.end)
        plans.append(VSSTPlan(prev.start, tail.end, ov, good=ov <= f))
    return plans


def plan_vssts_ref(keys: np.ndarray, kv_size: int, s_m: int, s_M: int, f: int,
                   fence_lo: np.ndarray, fence_hi: np.ndarray,
                   sst_size_l2: int) -> list[VSSTPlan]:
    """Segment-walk oracle for :func:`plan_vssts` (advances fence segment by
    fence segment; exact because overlap is constant between crossings)."""
    del sst_size_l2
    n = int(keys.shape[0])
    if n == 0:
        return []
    min_keys = max(1, s_m // kv_size)
    max_keys = max(min_keys, s_M // kv_size)

    if fence_lo.size:
        # For every key, the index of the first L2 SST whose *end* is >= key:
        # the count of SSTs intersected by [keys[i], keys[j]] is
        # seg_hi(j) - seg_lo(i) + (1 if keys[j] >= fence_lo[seg_hi(j)] else 0)
        # — but the segment-walk below only needs crossing positions.
        cross = np.unique(np.searchsorted(keys, fence_lo, side="left"))
        cross = cross[(cross > 0) & (cross < n)]
    else:
        cross = np.empty(0, np.int64)

    plans: list[VSSTPlan] = []
    i = 0
    while i < n:
        hard_end = min(n, i + max_keys)
        j_min = min(n, i + min_keys)
        ov_min = overlap_count_range(fence_lo, fence_hi,
                                     int(keys[i]), int(keys[j_min - 1]))
        if ov_min > f:
            # Poor vSST: close at S_m (paper: "their size is always S_m").
            plans.append(VSSTPlan(i, j_min, ov_min, good=False))
            i = j_min
            continue
        # Good vSST: extend while the L2-SST count stays <= f, up to S_M.
        # Advance whole fence segments at a time (count is constant between
        # crossings, so this is exact and O(#fences) instead of O(#keys)).
        j = j_min
        ov = ov_min
        while j < hard_end:
            nxt_idx = int(np.searchsorted(cross, j, side="right"))
            seg_end = int(cross[nxt_idx]) if nxt_idx < cross.size else n
            seg_end = min(seg_end, hard_end)
            if seg_end > j:
                j = seg_end
                ov = overlap_count_range(fence_lo, fence_hi,
                                         int(keys[i]), int(keys[j - 1]))
            if j >= hard_end:
                break
            ov_next = overlap_count_range(fence_lo, fence_hi,
                                          int(keys[i]), int(keys[j]))
            if ov_next > f:
                break
            j += 1
            ov = ov_next
        plans.append(VSSTPlan(i, j, ov, good=ov <= f))
        i = j
    # Absorb a too-small trailing plan into its predecessor.
    if len(plans) >= 2 and (plans[-1].end - plans[-1].start) < min_keys:
        tail = plans.pop()
        prev = plans.pop()
        ov = overlap_count_range(fence_lo, fence_hi,
                                 int(keys[prev.start]), int(keys[tail.end - 1]))
        plans.append(VSSTPlan(prev.start, tail.end, ov, good=ov <= f))
    return plans


def select_good_vssts(l1_ssts: list[SST], fence_lo: np.ndarray,
                      fence_hi: np.ndarray, sst_size_l2: int, f: int,
                      bytes_needed: int, ov: np.ndarray | None = None
                      ) -> list[int]:
    """§4.2.2: RocksDB's ratio scheduler over vSSTs, fully vectorized.

    Ranks every L1 vSST by ``overlap_bytes_in_L2 / size`` ascending (largest
    size with least overlap first), keeps only *good* candidates
    (L2-SST count ``<= f``), and picks until the cumulative size frees
    ``bytes_needed`` (== S_M, space for the next L0 SST).  Returns indices
    into ``l1_ssts``; empty only if L1 holds no good vSST (the paper's Φ=64
    failure mode, reproduced in benchmark fig13).

    ``ov`` — per-vSST L2 overlap counts — may be supplied precomputed (the
    LSM core passes one batched ``LevelIndex.overlap_counts`` query);
    otherwise it is derived here from the fence arrays.
    """
    if not l1_ssts:
        return []
    n = len(l1_ssts)
    sizes = np.fromiter((s.size for s in l1_ssts), np.int64, n)
    if ov is None:
        s_lo = np.fromiter((s.smallest for s in l1_ssts), np.int64, n)
        s_hi = np.fromiter((s.largest for s in l1_ssts), np.int64, n)
        if fence_lo.size:
            first = np.searchsorted(fence_hi, s_lo, side="left")
            last = np.searchsorted(fence_lo, s_hi, side="right")
            ov = np.maximum(0, last - first)
        else:
            ov = np.zeros(n, np.int64)
    ratio = ov * np.int64(sst_size_l2) / np.maximum(1, sizes)
    order = np.lexsort((np.arange(n), -sizes, ratio))
    picked, freed = [], 0
    for idx in order:
        if ov[idx] > f:        # poor vSST: never picked by the scheduler
            continue
        idx = int(idx)
        picked.append(idx)
        freed += int(sizes[idx])
        if freed >= bytes_needed:
            break
    return picked

"""Sorted-run merge backends.

Compaction is the paper's compute hot-spot; the core calls through this
module so the backend can be swapped:

* ``numpy``  — fast CPU path used by the discrete-event simulation.
* ``jnp``    — pure-jnp formulation (identical math to the Pallas oracle).
* ``pallas`` — the TPU merge-path kernel (``repro.kernels.merge_path``)
               executed in interpret mode; used by tests to prove the kernel
               is a drop-in for the store's merge.

All backends implement *latest-wins k-run merge*: runs are given newest
first; on duplicate keys the entry from the newest run (or the highest seq)
survives.  Within a single run keys are unique by construction.
"""

from __future__ import annotations

import numpy as np

_BACKEND = "numpy"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("numpy", "jnp", "pallas")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def merge_runs(runs: list[tuple[np.ndarray, np.ndarray]]
               ) -> tuple[np.ndarray, np.ndarray]:
    """Merge k sorted (keys, seqs) runs, dedup latest-wins by max seq.

    Seqs are globally unique and increase over time, so "latest wins" is
    exactly "max seq wins" — independent of run order.
    """
    runs = [r for r in runs if r[0].size]
    if not runs:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    if len(runs) == 1:
        return runs[0]
    if _BACKEND == "numpy":
        return _merge_numpy(runs)
    if _BACKEND == "jnp":
        return _merge_jnp(runs)
    return _merge_pallas(runs)


def _dedup_latest(keys: np.ndarray, seqs: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Given key-sorted, seq-ascending-within-key arrays, keep max-seq entry."""
    last = np.ones(keys.shape[0], dtype=bool)
    last[:-1] = keys[1:] != keys[:-1]
    return keys[last], seqs[last]


def _merge_numpy(runs) -> tuple[np.ndarray, np.ndarray]:
    keys = np.concatenate([r[0] for r in runs])
    seqs = np.concatenate([r[1] for r in runs])
    # Sort by (key, seq) so the last duplicate has the highest seq.
    order = np.lexsort((seqs, keys))
    return _dedup_latest(keys[order], seqs[order])


def _merge_jnp(runs) -> tuple[np.ndarray, np.ndarray]:
    import jax
    import jax.numpy as jnp

    with jax.experimental.enable_x64():   # keys are true int64
        keys = jnp.concatenate([jnp.asarray(r[0], jnp.int64) for r in runs])
        seqs = jnp.concatenate([jnp.asarray(r[1], jnp.int64) for r in runs])
        order = jnp.lexsort((seqs, keys))
        k, s = np.asarray(keys[order]), np.asarray(seqs[order])
    return _dedup_latest(k, s)


def _merge_pallas(runs) -> tuple[np.ndarray, np.ndarray]:
    """Reduce pairwise with the TPU merge-path kernel (interpret mode).

    The kernel performs a *stable* merge (ties: left run first), so feeding
    runs oldest-first keeps duplicate keys seq-ascending, which is what
    ``_dedup_latest`` needs.  (For a given key, a newer run's entry always
    carries a higher seqno.)
    """
    from repro.kernels.merge_path import ops as mp_ops

    ordered = runs[::-1]  # oldest first
    acc_k, acc_s = ordered[0]
    for k, s in ordered[1:]:
        acc_k, acc_s = mp_ops.merge_two_runs_np(acc_k, acc_s, k, s)
    return _dedup_latest(np.asarray(acc_k), np.asarray(acc_s))

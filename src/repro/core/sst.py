"""Sorted String Tables backed by numpy arrays.

An SST is an immutable sorted run of (key, seq) pairs.  Values are implicit:
the KV store's correctness contract is "a GET returns the payload written by
the highest-seqno PUT", so carrying the seqno is sufficient to verify
latest-wins semantics end-to-end (tests derive the payload as hash(key, seq)).
Physical size is ``n_keys * kv_size`` bytes, matching the paper's fixed-size
KV pairs (200 B in §5).
"""

from __future__ import annotations

import itertools
from contextlib import contextmanager

import numpy as np

_ids = itertools.count()
# uid-allocator override stack: when a tree routes SST identity through its
# own counter (trees beyond fleet slot 0 — see LSMTree), the top of this
# stack replaces the module counter for SSTs created inside the scope.
# Keeping slot 0 on the module counter preserves every single-tree uid
# stream byte-for-byte (the bloom-FP hash mixes sst.uid, and the
# read-parity capture pins those streams).
_alloc_stack: list = []


@contextmanager
def uid_allocator(src):
    """Scope SST uid assignment to ``src`` (an iterator; None keeps the
    process-global counter).  Trees wrap their structural entry points in
    this so a fleet's SST identities do not depend on the engine's
    event-interleaving order across trees."""
    if src is None:
        yield
        return
    _alloc_stack.append(src)
    try:
        yield
    finally:
        _alloc_stack.pop()


class SST:
    __slots__ = ("keys", "seqs", "kv_size", "uid", "n", "size", "smallest",
                 "largest")

    def __init__(self, keys: np.ndarray, seqs: np.ndarray, kv_size: int):
        assert keys.ndim == 1 and keys.shape == seqs.shape
        self.keys = keys
        self.seqs = seqs
        self.kv_size = kv_size
        self.uid = next(_alloc_stack[-1]) if _alloc_stack else next(_ids)
        # SSTs are immutable: metadata is materialized once (these fields
        # are on the structural hot path — total_size / fence rebuilds).
        n = int(keys.shape[0])
        self.n = n
        self.size = n * kv_size
        if n:
            self.smallest = int(keys[0])
            self.largest = int(keys[-1])
        else:
            self.smallest, self.largest = 0, -1   # empty range

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SST#{self.uid}[{self.smallest}..{self.largest}] n={self.n}"

    # ----------------------------------------------------------------- query
    def get(self, key: int) -> int | None:
        """Return seqno for key or None."""
        i = int(np.searchsorted(self.keys, key))
        if i < self.n and int(self.keys[i]) == key:
            return int(self.seqs[i])
        return None

    def may_contain(self, key: int) -> bool:
        return self.smallest <= key <= self.largest

    def scan_from(self, key: int, m: int) -> tuple[np.ndarray, np.ndarray]:
        """Up to ``m`` (keys, seqs) entries with key >= ``key``."""
        i = int(np.searchsorted(self.keys, key))
        return self.keys[i:i + m], self.seqs[i:i + m]

    def check_invariants(self) -> None:
        assert self.n > 0, "empty SST"
        d = np.diff(self.keys)
        assert np.all(d > 0), "SST keys must be strictly increasing"


def sst_from_sorted(keys: np.ndarray, seqs: np.ndarray, kv_size: int) -> SST:
    return SST(np.ascontiguousarray(keys), np.ascontiguousarray(seqs), kv_size)


def split_fixed(keys: np.ndarray, seqs: np.ndarray, kv_size: int,
                sst_size: int) -> list[SST]:
    """Split a sorted run into fixed-size SSTs of at most ``sst_size`` bytes."""
    per = max(1, sst_size // kv_size)
    out = []
    for i in range(0, keys.shape[0], per):
        out.append(SST(keys[i:i + per], seqs[i:i + per], kv_size))
    return out


def total_size(ssts: list[SST]) -> int:
    return sum(s.size for s in ssts)


def overlapping(ssts: list[SST], lo: int, hi: int) -> list[SST]:
    """SSTs from a *sorted, disjoint* level whose range intersects [lo, hi].

    The list-level oracle for the store's manifest queries: the LSM core
    itself routes through ``repro.core.level_index.LevelIndex``, which
    answers with the same two fence ranks over its flat arrays — the span
    is [first SST with largest >= lo, first SST with smallest > hi).
    """
    if not ssts:
        return []
    smallest = np.fromiter((s.smallest for s in ssts), np.int64, len(ssts))
    largest = np.fromiter((s.largest for s in ssts), np.int64, len(ssts))
    start = int(np.searchsorted(largest, lo, side="left"))
    end = int(np.searchsorted(smallest, hi, side="right"))
    return ssts[start:end]


def level_check_disjoint(ssts: list[SST]) -> None:
    """Invariant: leveled runs are sorted by key and pairwise disjoint."""
    for a, b in zip(ssts, ssts[1:]):
        assert a.largest < b.smallest, (
            f"overlapping leveled SSTs: {a} vs {b}")

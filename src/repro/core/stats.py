"""Instrumentation: I/O amplification, compaction chains, vSST quality, CPU proxy.

Every quantity the paper plots is derived from these counters:

* I/O amplification  = (flush + compaction device writes) / user bytes
* chain width/length = recorded per blocking L0 trigger (Figs 2 & 9)
* write stalls       = filled in by the DES (``repro.core.sim``)
* CPU efficiency     = cycle proxy from real work counters (merged keys,
                       per-key overlap probes, SSTs created / manifest
                       flushes) — the monotone stand-in for mpstat cycles/op.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class ChainRecord:
    """One compaction chain triggered to free space for L0/memtable."""

    length: int            # number of level-to-level stages
    width_bytes: int       # total bytes read+written across the chain
    stage_bytes: list[int] = field(default_factory=list)


# CPU-cycle proxy coefficients (constant across all policies, so ratios are
# meaningful): cycles per merged key, per overlap probe, per SST created,
# per manifest flush, per op baseline.
CYC_MERGE_KEY = 30.0
CYC_OVERLAP_PROBE = 60.0
CYC_SST_CREATE = 200_000.0
CYC_MANIFEST_FLUSH = 400_000.0
CYC_OP_BASE = 2_000.0


@dataclass
class Stats:
    # I/O accounting
    user_bytes: int = 0
    flush_bytes: int = 0
    compact_bytes_read: int = 0
    compact_bytes_written: int = 0
    device_reads: int = 0            # point-lookup block reads
    scan_blocks: int = 0             # range-scan device block reads
    # work counters (CPU proxy)
    merged_keys: int = 0
    overlap_probes: int = 0
    ssts_created: int = 0
    manifest_flushes: int = 0
    ops: int = 0
    # typed-op surface (DELETE tombstones, SCAN traffic)
    delete_ops: int = 0              # tombstones written (user DELETEs)
    scan_ops: int = 0
    tombstones_dropped: int = 0      # markers reclaimed at the bottom level
    tombstone_bytes_dropped: int = 0
    # structural records
    chains: list[ChainRecord] = field(default_factory=list)
    vssts_good: int = 0
    vssts_poor: int = 0
    vsst_good_bytes: int = 0
    vsst_poor_bytes: int = 0
    compactions_per_level: dict[int, int] = field(default_factory=dict)
    level_bytes_moved: dict[int, int] = field(default_factory=dict)

    # ------------------------------------------------------------- derived
    @property
    def write_amp(self) -> float:
        if self.user_bytes == 0:
            return 0.0
        return (self.flush_bytes + self.compact_bytes_written) / self.user_bytes

    @property
    def io_amp(self) -> float:
        """Read+write device traffic over user bytes (paper's I/O amp)."""
        if self.user_bytes == 0:
            return 0.0
        total = (self.flush_bytes + self.compact_bytes_written
                 + self.compact_bytes_read)
        return total / self.user_bytes

    @property
    def cpu_cycles_per_op(self) -> float:
        if self.ops == 0:
            return 0.0
        cyc = (CYC_MERGE_KEY * self.merged_keys
               + CYC_OVERLAP_PROBE * self.overlap_probes
               + CYC_SST_CREATE * self.ssts_created
               + CYC_MANIFEST_FLUSH * self.manifest_flushes
               + CYC_OP_BASE * self.ops)
        return cyc / self.ops

    @property
    def tombstones_live(self) -> int:
        """DELETE markers still occupying device space (space amplification
        pressure: written but not yet reclaimed at the bottom level)."""
        return max(0, self.delete_ops - self.tombstones_dropped)

    @property
    def mean_chain_width(self) -> float:
        if not self.chains:
            return 0.0
        return sum(c.width_bytes for c in self.chains) / len(self.chains)

    @property
    def max_chain_width(self) -> int:
        return max((c.width_bytes for c in self.chains), default=0)

    @property
    def mean_chain_length(self) -> float:
        if not self.chains:
            return 0.0
        return sum(c.length for c in self.chains) / len(self.chains)

    def note_compaction(self, level: int, bytes_moved: int) -> None:
        self.compactions_per_level[level] = self.compactions_per_level.get(level, 0) + 1
        self.level_bytes_moved[level] = self.level_bytes_moved.get(level, 0) + bytes_moved

    def summary(self) -> dict:
        out = {
            "io_amp": round(self.io_amp, 2),
            "write_amp": round(self.write_amp, 2),
            "chains": len(self.chains),
            "mean_chain_width_mb": round(self.mean_chain_width / 1e6, 3),
            "max_chain_width_mb": round(self.max_chain_width / 1e6, 3),
            "mean_chain_length": round(self.mean_chain_length, 2),
            "cycles_per_op": round(self.cpu_cycles_per_op, 0),
            "vssts_good": self.vssts_good,
            "vssts_poor": self.vssts_poor,
        }
        if self.delete_ops or self.scan_ops:
            out.update({
                "delete_ops": self.delete_ops,
                "scan_ops": self.scan_ops,
                "scan_blocks": self.scan_blocks,
                "tombstones_dropped": self.tombstones_dropped,
                "tombstones_live": self.tombstones_live,
            })
        return out

"""Instrumentation: I/O amplification, compaction chains, vSST quality, CPU proxy.

Every quantity the paper plots is derived from these counters:

* I/O amplification  = (flush + compaction device writes) / user bytes
* chain width/length = recorded per blocking L0 trigger (Figs 2 & 9)
* write stalls       = filled in by the DES (``repro.core.sim``)
* CPU efficiency     = cycle proxy from real work counters (merged keys,
                       per-key overlap probes, SSTs created / manifest
                       flushes) — the monotone stand-in for mpstat cycles/op.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

import numpy as np


@dataclass
class ChainRecord:
    """One first-class compaction chain: the cascade of dependent
    compaction :class:`~repro.core.lsm.Job` records triggered to free
    space for L0/memtable (``trigger="l0"``) or by the soft over-target
    sweep (``trigger="background"``).

    The structural fields are filled by ``LSMTree`` when the chain is
    emitted; the temporal fields (``t_start``/``t_finish``/``stall_s``)
    are filled by the DES scheduler once the chain's jobs get device
    time.  Paper semantics (§3): *width* is the head stage's input
    fan-in — L0 tiering merges ALL L0 SSTs plus the L1 overlap into one
    wide head, incremental designs pop a single SST — and *length* is
    the number of levels the chain traverses before the trigger clears.
    """

    chain_id: int = -1
    trigger: str = "l0"    # "l0" (flush-triggered) | "background"
    length: int = 0        # number of level-to-level stages (distinct levels)
    width: int = 0         # head fan-in: L0 SSTs the head consumed (the
                           # paper's tiering fan-in; background chains fall
                           # back to the head's total input SST count)
    width_bytes: int = 0   # total bytes read+written across the chain
    stage_bytes: list[int] = field(default_factory=list)
    n_jobs: int = 0
    job_uids: list[int] = field(default_factory=list)
    # filled by the DES scheduler:
    t_start: float = math.inf   # earliest job start on the device
    t_finish: float = 0.0       # latest job finish (the chain clears here)
    stall_s: float = 0.0        # foreground stall attributed to this chain

    @property
    def critical_path_s(self) -> float:
        """Wall-clock the chain occupied end-to-end on the device: the
        dependency edges serialize the stages, so this is the span from
        the first stage's start to the head's finish (0 if unscheduled)."""
        if not math.isfinite(self.t_start) or self.t_finish <= self.t_start:
            return 0.0
        return self.t_finish - self.t_start


@dataclass
class TenantLedger:
    """Per-tenant serving ledger (open-loop traffic layer).

    Written by ``repro.serving.traffic.serve`` into the owning shard's
    :class:`Stats` (one ledger per tenant per shard), so the fleet view
    aggregates tenants across shards like every other counter.  The
    conservation invariant — every offered op got exactly one verdict —
    is ``ops_offered == ops_admitted + ops_shed + ops_throttled``,
    re-asserted at runtime under ``cfg.paranoid_checks``.
    """

    name: str
    priority: int = 0
    slo_ms: float = 0.0
    ops_offered: int = 0
    ops_admitted: int = 0
    ops_shed: int = 0
    ops_throttled: int = 0
    slo_violations: int = 0         # admitted ops finishing past slo_ms

    @property
    def shed_frac(self) -> float:
        return self.ops_shed / max(1, self.ops_offered)

    @property
    def throttled_frac(self) -> float:
        return self.ops_throttled / max(1, self.ops_offered)

    @property
    def slo_violation_frac(self) -> float:
        return self.slo_violations / max(1, self.ops_admitted)

    def goodput_ops_s(self, duration_s: float) -> float:
        """Admitted ops that met the SLO, per second of measured time."""
        return (self.ops_admitted - self.slo_violations) \
            / max(duration_s, 1e-12)

    def merge_from(self, other: "TenantLedger") -> "TenantLedger":
        assert self.name == other.name, \
            f"merging ledgers of different tenants ({self.name} vs " \
            f"{other.name})"
        for f in ("ops_offered", "ops_admitted", "ops_shed",
                  "ops_throttled", "slo_violations"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        return self

    def summary(self) -> dict:
        return {
            "tenant": self.name,
            "priority": self.priority,
            "slo_ms": self.slo_ms,
            "ops_offered": self.ops_offered,
            "shed_frac": round(self.shed_frac, 4),
            "throttled_frac": round(self.throttled_frac, 4),
            "slo_violation_frac": round(self.slo_violation_frac, 4),
        }


# CPU-cycle proxy coefficients (constant across all policies, so ratios are
# meaningful): cycles per merged key, per overlap probe, per SST created,
# per manifest flush, per op baseline.
CYC_MERGE_KEY = 30.0
CYC_OVERLAP_PROBE = 60.0
CYC_SST_CREATE = 200_000.0
CYC_MANIFEST_FLUSH = 400_000.0
CYC_OP_BASE = 2_000.0


@dataclass
class Stats:
    # I/O accounting
    user_bytes: int = 0
    flush_bytes: int = 0
    compact_bytes_read: int = 0
    compact_bytes_written: int = 0
    device_reads: int = 0            # point-lookup block reads
    scan_blocks: int = 0             # range-scan device block reads
    # work counters (CPU proxy)
    merged_keys: int = 0
    overlap_probes: int = 0
    ssts_created: int = 0
    manifest_flushes: int = 0
    ops: int = 0
    # typed-op surface (DELETE tombstones, SCAN traffic)
    delete_ops: int = 0              # tombstones written (user DELETEs)
    scan_ops: int = 0
    tombstones_dropped: int = 0      # markers reclaimed at the bottom level
    tombstone_bytes_dropped: int = 0
    # structural records: the chain ledger (ALL chains, l0 + background;
    # chain_index is the DES's O(1) chain_id -> record lookup)
    chains: list[ChainRecord] = field(default_factory=list)
    chain_index: dict[int, ChainRecord] = field(default_factory=dict)
    vssts_good: int = 0
    vssts_poor: int = 0
    vsst_good_bytes: int = 0
    vsst_poor_bytes: int = 0
    compactions_per_level: dict[int, int] = field(default_factory=dict)
    level_bytes_moved: dict[int, int] = field(default_factory=dict)
    # serving-layer admission accounting (repro.serving): offered traffic
    # ops routed to this shard and their verdicts; ops never silently
    # dropped — shed + throttled + admitted == offered per tenant
    ops_offered: int = 0
    ops_shed: int = 0
    ops_throttled: int = 0
    slo_violations: int = 0
    tenants: dict[str, TenantLedger] = field(default_factory=dict)

    # ------------------------------------------------------------- derived
    @property
    def write_amp(self) -> float:
        if self.user_bytes == 0:
            return 0.0
        return (self.flush_bytes + self.compact_bytes_written) / self.user_bytes

    @property
    def io_amp(self) -> float:
        """Read+write device traffic over user bytes (paper's I/O amp)."""
        if self.user_bytes == 0:
            return 0.0
        total = (self.flush_bytes + self.compact_bytes_written
                 + self.compact_bytes_read)
        return total / self.user_bytes

    @property
    def cpu_cycles_per_op(self) -> float:
        if self.ops == 0:
            return 0.0
        cyc = (CYC_MERGE_KEY * self.merged_keys
               + CYC_OVERLAP_PROBE * self.overlap_probes
               + CYC_SST_CREATE * self.ssts_created
               + CYC_MANIFEST_FLUSH * self.manifest_flushes
               + CYC_OP_BASE * self.ops)
        return cyc / self.ops

    @property
    def tombstones_live(self) -> int:
        """DELETE markers still occupying device space (space amplification
        pressure: written but not yet reclaimed at the bottom level)."""
        return max(0, self.delete_ops - self.tombstones_dropped)

    # --------------------------------------------------- the chain ledger
    def record_chain(self, rec: ChainRecord) -> ChainRecord:
        """Append a chain to the ledger and index it for the DES."""
        self.chains.append(rec)
        self.chain_index[rec.chain_id] = rec
        return rec

    @property
    def l0_chains(self) -> list[ChainRecord]:
        """Flush-triggered chains only — the paper's Figs 2 & 9 population
        (background soft-limit sweeps are ledgered but reported apart)."""
        return [c for c in self.chains if c.trigger == "l0"]

    @property
    def mean_chain_width(self) -> float:
        chains = self.l0_chains
        if not chains:
            return 0.0
        return sum(c.width_bytes for c in chains) / len(chains)

    @property
    def max_chain_width(self) -> int:
        return max((c.width_bytes for c in self.l0_chains), default=0)

    @property
    def mean_chain_length(self) -> float:
        chains = self.l0_chains
        if not chains:
            return 0.0
        return sum(c.length for c in chains) / len(chains)

    @property
    def mean_chain_fanin(self) -> float:
        """Mean head-stage L0 fan-in over flush-triggered chains — the
        paper's chain *width* in file terms (tiering ~= l0_max_ssts,
        incremental = 1)."""
        chains = self.l0_chains
        if not chains:
            return 0.0
        return sum(c.width for c in chains) / len(chains)

    @property
    def effective_chain_length(self) -> float:
        """Compaction stages each L0 relief *forces*, counting the debt
        catch-up that debt designs defer into background sweeps: total
        stages across the whole ledger over the number of flush-triggered
        chains.  For no-debt policies this equals the raw mean length;
        for debt designs it surfaces the deferred part of the cascade —
        the paper's chain *length* on an equal footing across policies."""
        n_l0 = len(self.l0_chains)
        if n_l0 == 0:
            return 0.0
        return sum(c.length for c in self.chains) / n_l0

    def chain_report(self) -> dict:
        """Distribution summary of the chain ledger (the chain observatory).

        Width (head fan-in, SSTs), length (levels traversed), and
        critical-path duration P50/P99 over flush-triggered chains, plus
        the background-chain count and the total foreground stall time
        the DES attributed to chains.  This is the payload of db_bench's
        ``chain_report`` rows (see ``docs/benchmarks.md``)."""
        chains = self.l0_chains
        out = {
            "n_chains": len(chains),
            "n_background_chains": len(self.chains) - len(chains),
            "stall_attributed_s": round(
                sum(c.stall_s for c in self.chains), 4),
        }
        if not chains:
            return out
        width = np.array([c.width for c in chains], np.float64)
        length = np.array([c.length for c in chains], np.float64)
        crit = np.array([c.critical_path_s for c in chains], np.float64)
        out.update({
            "mean_width_ssts": round(float(width.mean()), 2),
            "p50_width_ssts": float(np.percentile(width, 50)),
            "p99_width_ssts": float(np.percentile(width, 99)),
            "max_width_ssts": int(width.max()),
            "mean_length": round(float(length.mean()), 2),
            "effective_length": round(self.effective_chain_length, 2),
            "p50_length": float(np.percentile(length, 50)),
            "p99_length": float(np.percentile(length, 99)),
            "max_length": int(length.max()),
            "p50_critical_path_ms": round(
                float(np.percentile(crit, 50)) * 1e3, 3),
            "p99_critical_path_ms": round(
                float(np.percentile(crit, 99)) * 1e3, 3),
            "mean_width_mb": round(self.mean_chain_width / 1e6, 3),
        })
        return out

    def merge_from(self, other: "Stats") -> "Stats":
        """Accumulate another ledger into this one (fleet aggregation):
        numeric counters add, chain ledgers concatenate (chain ids are
        process-global so the merged index stays collision-free), per-level
        dicts merge-add.  Returns self."""
        for f in dataclasses.fields(Stats):
            if f.name in ("chains", "chain_index", "tenants",
                          "compactions_per_level", "level_bytes_moved"):
                continue
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        self.chains.extend(other.chains)
        self.chain_index.update(other.chain_index)
        for name, led in other.tenants.items():
            if name in self.tenants:
                self.tenants[name].merge_from(led)
            else:
                self.tenants[name] = dataclasses.replace(led)
        for lvl, n in other.compactions_per_level.items():
            self.compactions_per_level[lvl] = \
                self.compactions_per_level.get(lvl, 0) + n
        for lvl, b in other.level_bytes_moved.items():
            self.level_bytes_moved[lvl] = \
                self.level_bytes_moved.get(lvl, 0) + b
        return self

    def note_compaction(self, level: int, bytes_moved: int) -> None:
        self.compactions_per_level[level] = self.compactions_per_level.get(level, 0) + 1
        self.level_bytes_moved[level] = self.level_bytes_moved.get(level, 0) + bytes_moved

    def summary(self) -> dict:
        out = {
            "io_amp": round(self.io_amp, 2),
            "write_amp": round(self.write_amp, 2),
            "chains": len(self.l0_chains),
            "bg_chains": len(self.chains) - len(self.l0_chains),
            "mean_chain_width_mb": round(self.mean_chain_width / 1e6, 3),
            "max_chain_width_mb": round(self.max_chain_width / 1e6, 3),
            "mean_chain_length": round(self.mean_chain_length, 2),
            "cycles_per_op": round(self.cpu_cycles_per_op, 0),
            "vssts_good": self.vssts_good,
            "vssts_poor": self.vssts_poor,
        }
        if self.delete_ops or self.scan_ops:
            out.update({
                "delete_ops": self.delete_ops,
                "scan_ops": self.scan_ops,
                "scan_blocks": self.scan_blocks,
                "tombstones_dropped": self.tombstones_dropped,
                "tombstones_live": self.tombstones_live,
            })
        if self.ops_offered:
            admitted = (self.ops_offered - self.ops_shed
                        - self.ops_throttled)
            out.update({
                "ops_offered": self.ops_offered,
                "ops_shed": self.ops_shed,
                "ops_throttled": self.ops_throttled,
                "shed_frac": round(self.ops_shed / self.ops_offered, 4),
                "slo_violation_frac": round(
                    self.slo_violations / max(1, admitted), 4),
                "per_tenant": [self.tenants[k].summary()
                               for k in sorted(self.tenants)],
            })
        return out


class FleetStats:
    """Read-only fleet-wide view over a sharded store's per-shard ledgers.

    Each shard's :class:`LSMTree` writes into its OWN :class:`Stats`
    (per-shard observability stays first-class); this wrapper aggregates
    them on demand into the familiar ``Stats`` read API — ``io_amp``,
    ``chains``, ``summary()``, ``chain_report()``, … all delegate to a
    freshly merged snapshot, so a `FleetStats` can stand wherever a
    ``Stats`` is only *read*.  Writes are refused (``__setattr__``): the
    DES and the trees must mutate the owning shard's ledger directly, or
    fleet counters would silently land in a throwaway snapshot.
    """

    def __init__(self, shards: list[Stats]):
        object.__setattr__(self, "shards", list(shards))

    def __setattr__(self, name, value):
        raise AttributeError(
            "FleetStats is a read-only aggregate; mutate the per-shard "
            "Stats (FleetStats.shards[i]) instead")

    def merged(self) -> Stats:
        """A fresh Stats holding the fleet-wide aggregate (counters
        summed, chain ledgers concatenated shard-major)."""
        out = Stats()
        for st in self.shards:
            out.merge_from(st)
        return out

    # Stats methods that mutate their receiver: reached through
    # __getattr__ they would operate on the throwaway merged snapshot
    # and vanish silently, so refuse them like attribute writes.
    _MUTATORS = frozenset({"note_compaction", "record_chain", "merge_from"})

    def __reduce__(self):
        # Explicit pickle protocol: the default path probes
        # ``__getstate__`` via getattr, which lands in __getattr__ →
        # merged() → self.shards → __getattr__ … and recurses forever.
        return (FleetStats, (self.shards,))

    def __getattr__(self, name):
        # every Stats read (property, counter, or method) via the merged
        # snapshot; AttributeError propagates naturally for unknown names.
        # Dunder probes (pickle/copy protocol discovery, IPython reprs)
        # must fail fast instead of delegating into merged().
        if name.startswith("__") and name.endswith("__"):
            raise AttributeError(name)
        if name in FleetStats._MUTATORS:
            raise AttributeError(
                f"Stats.{name} mutates its receiver; call it on the "
                f"owning shard's Stats (FleetStats.shards[i]), not the "
                f"read-only aggregate")
        return getattr(self.merged(), name)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def per_shard_summary(self) -> list[dict]:
        """One ``Stats.summary()`` row per shard, shard order."""
        return [st.summary() for st in self.shards]

    def chain_report(self) -> dict:
        """Fleet chain observatory: the merged distributions plus a
        ``per_shard`` breakdown (chain counts + attributed stall per
        shard) — the cross-shard interference signal: ONE hot shard's
        chains soaking up the stall attribution while every shard's
        reads ride the same busy device."""
        out = self.merged().chain_report()
        out["per_shard"] = [
            {
                "shard": s,
                "n_chains": len(st.l0_chains),
                "n_background_chains": len(st.chains) - len(st.l0_chains),
                "stall_attributed_s": round(
                    sum(c.stall_s for c in st.chains), 4),
                "io_amp": round(st.io_amp, 2),
            }
            for s, st in enumerate(self.shards)
        ]
        return out

    def summary(self) -> dict:
        out = self.merged().summary()
        user = [st.user_bytes for st in self.shards]
        total = sum(user)
        if total:
            # write-load-balance signal: hottest shard's share of user
            # bytes, whole run (1/n_shards = perfectly balanced).  Named
            # apart from shard_sweep's hot_shard_frac, which is the
            # hottest shard's share of measured-phase OPS.
            out["hot_shard_bytes_frac"] = round(max(user) / total, 3)
        return out

"""Discrete-event simulation of the KV server (open-loop, §5 methodology).

The paper measures tail latency with a modified YCSB that issues requests at
a *fixed rate* into an unbounded queue (coordinated-omission-free).  The sim
reproduces that exactly:

* arrivals are deterministic (rate R) — the open-loop generator;
* foreground service is ONE FIFO queue **per shard** (``cfg.n_shards``;
  one queue total for the classic single-tree store) with per-kind costs
  (:class:`repro.core.types.OpKind`): constant CPU for PUT/DELETE, per-GET
  service from the store's *actual* probe work (device block reads ×
  device model), per-SCAN service from the files seeked and blocks spanned
  (sequential transfer) — read kinds are inflated while compactions keep
  the device busy;
* background work (flushes + compaction chains emitted by the eager
  structural LSM in :mod:`repro.core.lsm`) runs on slot pools **shared by
  every shard** (``DeviceModel.compaction_slots`` — the device does not
  multiply with the shard count); job durations come from real bytes;
  jobs *sharing a source level* in the same tree serialize (RocksDB's
  per-level compaction exclusivity — the reason wide tiering chains cannot
  hide behind thread parallelism), while independent levels — and
  independent shards — overlap;
* structural events advance on the **processed clock**: a memtable fills
  when its last PUT is *serviced* (exact Lindley recursion maintained
  incrementally per shard), so under saturation compaction triggers spread
  out the way a real store's do instead of bunching at arrival time;
* write stalls are computed from *temporal* L0 occupancy per tree: every
  flushed SST occupies an L0 slot until the compaction job that consumed
  it finishes; a fill event stalls when occupancy ≥ the stop limit
  (RocksDB's write-stop), or when the previous flush is still in flight
  (write-buffer stall);
* end-to-end latency is the exact Lindley recursion over each shard's
  queue, vectorized:  D_i = S_i + max_{j<=i}(arr_j - S_{j-1}),
  lat_i = D_i - arr_i — then re-gathered in arrival order.

Sharding (``cfg.n_shards > 1``) couples the shards *only* through the
device: the foreground queues are independent, but all flushes and
compaction chains contend for the same slot pools and every shard's read
service is inflated by the global count of running compactions — one
shard's wide chain raises every shard's read tail (the cross-shard
interference scenario ``db_bench``'s ``shard_sweep`` measures).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from ..analysis.sanitizer import maybe_sanitizer
from .lsm import Job, LSMTree
from .policies import get_policy
from .shard import ShardRouter
from .stats import FleetStats, Stats
from .types import DeviceModel, LSMConfig, OpKind, RequestBatch
from .uids import UidNamespace

PUT_SERVICE = 1.5e-6      # CPU service per put/delete (s); ~0.7 Mops/s queue
GET_CPU = 2.0e-6          # CPU service per get before device reads
SCAN_CPU = 4.0e-6         # CPU service per scan before device reads (seek
                          # setup + iterator merge overhead)
SCAN_FILE_CPU = 2.0e-6    # per-file iterator CPU (heap entry, index block)
BUSY_ALPHA = 0.6          # read-service inflation per concurrently-running job


@dataclass
class SimResult:
    arrivals: np.ndarray
    latency: np.ndarray            # end-to-end per op (s)
    op_types: np.ndarray           # OpKind values (0 put, 1 get, 2 del, 3 scan)
    stall_total: float = 0.0
    stall_max: float = 0.0
    n_stalls: int = 0
    stats: Stats | FleetStats | None = None
    job_log: list[Job] = field(default_factory=list)
    makespan: float = 0.0
    get_reads: np.ndarray | None = None    # per-op device block reads
    get_probed: np.ndarray | None = None   # per-op SSTs probed (GET + SCAN)
    shard_ids: np.ndarray | None = None    # per-op shard (None: single tree)
    n_shards: int = 1
    stall_events: list[tuple[int, float]] = field(default_factory=list)
    # per-shard chain-ledger snapshot AT RESULT TIME: chain count and the
    # write-stop seconds the DES attributed to each shard's chains.  The
    # fleet engine's Stats are shared across temporal passes (the ledger's
    # temporal fields reflect the most recent pass), so per-pass results
    # carry their own snapshot here.
    chain_counts: list[int] | None = None
    chain_stall_s: list[float] | None = None

    def pct(self, q: float, op: int | None = None) -> float:
        lat = self.latency if op is None else self.latency[self.op_types == op]
        if lat.size == 0:
            return 0.0
        return float(np.percentile(lat, q))

    @property
    def p99(self) -> float:
        return self.pct(99)

    @property
    def p99_put(self) -> float:
        return self.pct(99, 0)

    @property
    def p99_get(self) -> float:
        return self.pct(99, 1)

    @property
    def p99_scan(self) -> float:
        return self.pct(99, int(OpKind.SCAN))

    # The paper reports P99.9 tails (§5); surface them per kind too.
    @property
    def p999(self) -> float:
        return self.pct(99.9)

    @property
    def p999_put(self) -> float:
        return self.pct(99.9, 0)

    @property
    def p999_get(self) -> float:
        return self.pct(99.9, 1)

    @property
    def p999_scan(self) -> float:
        return self.pct(99.9, int(OpKind.SCAN))

    @property
    def throughput(self) -> float:
        return self.arrivals.shape[0] / max(self.makespan, 1e-9)

    def chain_report(self) -> dict:
        """Chain observatory: width/length/critical-path distributions of
        the run's compaction chains (``Stats.chain_report``)."""
        return self.stats.chain_report() if self.stats is not None else {}

    def completions_timeline(self, bins: int = 100) -> tuple[np.ndarray, np.ndarray]:
        done = self.arrivals + self.latency
        hist, edges = np.histogram(done, bins=bins)
        centers = 0.5 * (edges[1:] + edges[:-1])
        widths = np.diff(edges)
        return centers, hist / np.maximum(widths, 1e-12)

    def summary(self) -> dict:
        out = {
            "p50_ms": round(self.pct(50) * 1e3, 3),
            "p90_ms": round(self.pct(90) * 1e3, 3),
            "p99_ms": round(self.pct(99) * 1e3, 3),
            "p999_ms": round(self.p999 * 1e3, 3),
            "p99_put_ms": round(self.p99_put * 1e3, 3),
            "p99_get_ms": round(self.p99_get * 1e3, 3),
            "p999_put_ms": round(self.p999_put * 1e3, 3),
            "p999_get_ms": round(self.p999_get * 1e3, 3),
            "stall_total_s": round(self.stall_total, 4),
            "stall_max_s": round(self.stall_max, 4),
            "n_stalls": self.n_stalls,
            "kops_s": round(self.throughput / 1e3, 1),
        }
        if (self.op_types == OpKind.SCAN).any():
            out["p99_scan_ms"] = round(self.p99_scan * 1e3, 3)
            out["p999_scan_ms"] = round(self.p999_scan * 1e3, 3)
        if self.stats is not None:
            out.update(self.stats.summary())
        return out

    def per_shard_summary(self) -> list[dict]:
        """Per-shard latency/stall breakdown (fleet runs only; a single
        tree returns one row covering every op).  The cross-shard
        interference signal reads directly off these rows: the hot
        shard's stall seconds against every shard's inflated read tail."""
        if self.shard_ids is None:
            shard_ids = np.zeros(self.latency.shape[0], np.int64)
        else:
            shard_ids = self.shard_ids
        # every shard gets a row, including trailing shards no op routed to
        n_shards = max(self.n_shards,
                       int(shard_ids.max()) + 1 if shard_ids.size else 1)
        rows = []
        for s in range(n_shards):
            m = shard_ids == s
            lat = self.latency[m]
            kinds = self.op_types[m]
            stalls = [d for i, d in self.stall_events
                      if shard_ids[i] == s]
            row = {
                "shard": s,
                "ops": int(m.sum()),
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3)
                if lat.size else 0.0,
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3)
                if lat.size else 0.0,
                "p999_ms": round(float(np.percentile(lat, 99.9)) * 1e3, 3)
                if lat.size else 0.0,
                "stall_total_s": round(sum(stalls), 4),
                "n_stalls": len(stalls),
            }
            g = lat[kinds == OpKind.GET]
            if g.size:
                row["p99_get_ms"] = round(float(np.percentile(g, 99)) * 1e3, 3)
            rows.append(row)
        return rows


@dataclass
class _RunState:
    """Everything :meth:`Simulator._setup` derives from an op stream before
    any engine-specific event processing starts (shared by the heap loop
    and the fleet engine)."""

    n: int
    op_types: np.ndarray
    keys: np.ndarray
    arrivals: np.ndarray
    scan_lens: np.ndarray
    service: np.ndarray
    get_reads: np.ndarray
    get_probed: np.ndarray
    block_t: float
    shard_ids: np.ndarray
    regions: np.ndarray
    ev_by_shard: list[list[tuple[int, int]]]
    shard_pos: list[np.ndarray]


class SlotPool:
    """Background executor: earliest-free-slot scheduling with job deps and
    per-(region, source-level) exclusivity.

    ``sanitizer`` (``REPRO_SANITIZE=1``) audits every assignment it makes
    — chain edges honoured, no double-occupied (tree, level) slot — at
    the cost of one ``None`` check per job otherwise.
    """

    def __init__(self, n_slots: int, sanitizer=None):
        self.free_at = [0.0] * max(1, n_slots)
        self.level_free: dict[tuple[int, int], float] = {}
        self.sanitizer = sanitizer

    def schedule(self, job: Job, ready: float, duration: float,
                 region: int = 0) -> None:
        dep_ready = max((d.t_finish for d in job.deps), default=0.0)
        lkey = (region, job.level)
        start = max(ready, dep_ready, self.level_free.get(lkey, 0.0))
        slot = min(range(len(self.free_at)), key=lambda i: self.free_at[i])
        start = max(start, self.free_at[slot])
        job.t_start = start
        job.t_finish = start + duration
        job.scheduled = True
        self.free_at[slot] = job.t_finish
        self.level_free[lkey] = job.t_finish
        if self.sanitizer is not None:
            self.sanitizer.on_schedule(region, job)


class ChainScheduler(SlotPool):
    """Chain-aware priority scheduler for the compaction pool.

    A drained batch of compaction jobs is grouped by ``chain_id`` and the
    chains are ordered by head urgency before slot assignment: chains
    whose head relieves L0 pressure go first (RocksDB's low-pri pool
    boosts L0->L1 work for exactly this reason), background soft-limit
    sweeps last; the policy object's ``chain_priority`` hook supplies the
    sort key.  Independent chains still run concurrently — priority only
    decides who gets the earliest free slot — while intra-chain
    dependency edges stay serialized via ``parent_job.t_finish`` (parents
    are always scheduled before their children because emission order
    within a chain is dependency order).
    """

    @staticmethod
    def rank_batch(jobs_durs: list[tuple[Job, float]],
                   priority_fn) -> list[tuple[Job, float]]:
        """Order one drained batch for slot assignment.
        ``priority_fn(chain_jobs)`` maps a chain's jobs (emission order,
        head last) to a sortable urgency key — lower schedules earlier;
        ties keep emission (FIFO) order.  Pure function of the jobs: the
        fleet engine ranks each batch once and replays the order across
        temporal passes."""
        order: list[int] = []
        groups: dict[int, list[tuple[Job, float]]] = {}
        for job, dur in jobs_durs:
            if job.chain_id not in groups:
                groups[job.chain_id] = []
                order.append(job.chain_id)
            groups[job.chain_id].append((job, dur))
        ranked = sorted(order,
                        key=lambda cid: priority_fn([j for j, _ in
                                                     groups[cid]]))
        return [jd for cid in ranked for jd in groups[cid]]

    def schedule_seq(self, ranked: list[tuple[Job, float]],
                     ready: float, region: int) -> None:
        """Assign slots to an already-ranked sequence."""
        for job, dur in ranked:
            self.schedule(job, ready, dur, region)

    def schedule_batch(self, jobs_durs: list[tuple[Job, float]],
                       ready: float, region: int, priority_fn) -> None:
        """Rank one drained batch by chain urgency, then assign slots."""
        self.schedule_seq(self.rank_batch(jobs_durs, priority_fn),
                          ready, region)


class Simulator:
    """The DES: per-shard foreground queues over one shared device.

    ``cfg.n_shards == 1`` is the classic engine — one foreground queue,
    optionally ``n_regions`` trees behind it (the paper's Fig 10 region
    experiment) — and stays byte-identical to the pre-sharding code.
    ``cfg.n_shards > 1`` partitions the keyspace (``ShardRouter``) over
    per-shard trees, each with its own queue/memtable/stall state, all
    sharing the flush slot and the chain-aware compaction pool.
    """

    def __init__(self, cfg: LSMConfig, device: DeviceModel | None = None,
                 n_regions: int = 1, uids: UidNamespace | None = None):
        self.cfg = cfg
        # Engine-private uid streams (None = legacy module-global counters
        # + reset_uid_counters idiom); see repro.core.uids.
        self.uids = uids
        # Stall gates (write-stop occupancy, write-buffer allowance) are the
        # compaction policy's call, not an enum branch.
        self.policy = get_policy(cfg.policy)
        self.device = device or DeviceModel()
        # Scan block accounting happens in the tree (cfg.block_size) while
        # scan service pricing happens here (device.block_size): keep the
        # two granularities from silently diverging.
        assert cfg.block_size == self.device.block_size, \
            "LSMConfig.block_size must match DeviceModel.block_size"
        self.n_shards = cfg.n_shards
        assert self.n_shards == 1 or n_regions == 1, \
            "regions subdivide a single-shard store; a sharded fleet " \
            "keeps one region per shard"
        self.n_regions = n_regions
        self.router = ShardRouter.from_config(cfg)
        # One Stats ledger per shard; n_shards == 1 keeps the legacy shape
        # (all region trees share THE Stats), a fleet gets a read-only
        # aggregate view over the per-shard ledgers.
        self.shard_stats = [Stats() for _ in range(self.n_shards)]
        self.stats: Stats | FleetStats = self.shard_stats[0] \
            if self.n_shards == 1 else FleetStats(self.shard_stats)
        # Flat shard-major tree list: trees[shard * n_regions + region].
        self.trees = [LSMTree(cfg, self.shard_stats[s], shard_id=s,
                              region_id=r, uids=uids)
                      for s in range(self.n_shards)
                      for r in range(n_regions)]
        # Dedicated flush slot + shared compaction slots (RocksDB's
        # high-priority flush pool vs low-priority compaction pool) —
        # shared across ALL shards: the device doesn't grow with the
        # fleet, which is exactly the contention under study.
        # REPRO_SANITIZE=1: runtime schedule sanitizer (None when off)
        self.sanitizer = maybe_sanitizer()
        self.flush_pool = SlotPool(1, sanitizer=self.sanitizer)
        self.compact_pool = ChainScheduler(
            max(1, self.device.compaction_slots - 1),
            sanitizer=self.sanitizer)
        # temporal L0 occupancy per tree: [appear_t, clears_at,
        # clearing_chain_id] entries (chain_id -1 until consumed — used to
        # attribute write-stop stall time to the chain that clears it)
        n_trees = self.n_shards * n_regions
        self.l0_entries: list[list[list]] = [[] for _ in range(n_trees)]
        self.flush_inflight: list[list[float]] = [[] for _ in range(n_trees)]
        self.job_log: list[Job] = []
        self.stall_events: list[tuple[int, float]] = []  # (op_idx, duration)

    # ------------------------------------------------------------------
    def _job_duration(self, job: Job) -> float:
        d = self.device
        return (d.read_time(job.bytes_read, max(1, job.n_in_ssts))
                + d.write_time(job.bytes_written, max(1, job.n_out_ssts)))

    def _chain_key(self, chain_jobs: list[Job]):
        """Priority key for one chain (emission order, head last) — the
        policy object's ``chain_priority`` hook, fed the chain head."""
        return self.policy.chain_priority(self.cfg, chain_jobs[-1],
                                          chain_jobs)

    def _schedule_drained(self, tree: LSMTree, tree_idx: int,
                          t: float) -> None:
        self._schedule_jobs(tree.drain_jobs(), tree_idx, t)

    def _schedule_jobs(self, drained: list[Job], tree_idx: int,
                       t: float) -> None:
        # Compactions first (priority-ordered by chain urgency), then
        # flushes: a flush's only dep is a compaction chain head, so its
        # dep is always scheduled by the time the flush pool sees it.
        # tree_idx namespaces the per-(tree, level) exclusivity key: two
        # shards' L1 compactions are independent and may overlap.
        compacts = [(j, self._job_duration(j)) for j in drained
                    if j.kind == "compact"]
        if compacts:
            if self.cfg.chain_aware_sched:
                self.compact_pool.schedule_batch(compacts, t, tree_idx,
                                                 self._chain_key)
            else:
                for job, dur in compacts:     # legacy FIFO drain order
                    self.compact_pool.schedule(job, t, dur, tree_idx)
            for job, _dur in compacts:        # emission order, like drain
                if job.level == 0 and job.l0_consumed:
                    self._consume_l0(tree_idx, job.l0_consumed, job.t_finish,
                                     job.chain_id)
                self._note_scheduled(job)
                self.job_log.append(job)
        for job in drained:
            if job.kind != "flush":
                continue
            self.flush_pool.schedule(job, t, self._job_duration(job),
                                     tree_idx)
            self.flush_inflight[tree_idx].append(job.t_finish)
            if job.bytes_written > 0:
                # SST appears in L0 when the flush lands.
                self.l0_entries[tree_idx].append([job.t_finish, np.inf, -1])
            self.job_log.append(job)

    def _note_scheduled(self, job: Job) -> None:
        """Fill the chain ledger's temporal fields and (paranoid) validate
        the intra-chain dependency edge the scheduler just honoured."""
        rec = self.shard_stats[job.shard].chain_index.get(job.chain_id)
        if rec is not None:
            rec.t_start = min(rec.t_start, job.t_start)
            rec.t_finish = max(rec.t_finish, job.t_finish)
        if self.cfg.paranoid_checks and job.parent_job is not None:
            assert job.t_start >= job.parent_job.t_finish - 1e-9, \
                "chain child scheduled before its parent finished"

    def _consume_l0(self, tree_idx: int, k: int, clears_at: float,
                    chain_id: int = -1) -> None:
        pending = [e for e in self.l0_entries[tree_idx] if e[1] == np.inf]
        pending.sort(key=lambda e: e[0])
        for e in pending[:k]:
            e[1] = clears_at
            e[2] = chain_id

    def _l0_stall(self, tree_idx: int, t: float) -> tuple[float, int]:
        """Wait until temporal L0 occupancy drops below the stop limit.
        Returns ``(stall, chain_id)`` — the chain whose head clears the
        slot the queue waits for (-1 when unknown); the caller attributes
        the stall to that chain only when the L0 wait is the binding
        component of the fill event's delay."""
        if self.sanitizer is not None:
            self.sanitizer.on_gate(tree_idx, t)
        stop = self.policy.l0_stop_ssts(self.cfg)
        entries = self.l0_entries[tree_idx]
        # Per-tree event times are nondecreasing (global event heap), so an
        # SST cleared by now can never gate again: drop it for good rather
        # than re-filtering the full history every event.
        live = [e for e in entries if e[1] > t]
        if len(live) != len(entries):
            self.l0_entries[tree_idx] = live
        active = sorted((e[1], e[2]) for e in live if e[0] <= t)
        if len(active) < stop:
            return 0.0, -1
        k = len(active) - stop  # waiting for the (k+1)-th clear
        target, cid = active[k]
        if not np.isfinite(target):
            target = max(self.compact_pool.free_at)
            cid = -1
        return max(0.0, target - t), int(cid)

    def _wb_stall(self, tree_idx: int, t: float) -> float:
        """Write-buffer stall: previous flush still in flight."""
        if self.sanitizer is not None:
            self.sanitizer.on_gate(tree_idx, t)
        unfinished = sorted(f for f in self.flush_inflight[tree_idx] if f > t)
        self.flush_inflight[tree_idx] = unfinished  # finished never gate again
        allowed = self.policy.write_buffer_limit(self.cfg) - 1
        if len(unfinished) < allowed:
            return 0.0
        return unfinished[len(unfinished) - allowed] - t

    # ------------------------------------------------------------------
    def _setup(self, op_types: np.ndarray, keys: np.ndarray,
               arrivals: np.ndarray,
               scan_lens: np.ndarray | None) -> "_RunState":
        """Shared run prologue: validate/normalize the op stream, price the
        base per-kind service, route ops to shards/regions and derive the
        fill-event schedule.  Both engines — the heap loop here and the
        two-phase :class:`repro.core.fleet.FleetEngine` — start from the
        exact same :class:`_RunState`."""
        n = op_types.shape[0]
        assert keys.shape[0] == n and arrivals.shape[0] == n and n > 0
        cfg = self.cfg
        kpm = cfg.keys_per_memtable
        op_types = np.ascontiguousarray(op_types, np.uint8)
        if scan_lens is None:
            assert not (op_types == OpKind.SCAN).any(), \
                "SCAN ops require scan_lens"
            scan_lens = np.zeros(n, np.int32)
        scan_lens = np.ascontiguousarray(scan_lens, np.int32)
        service = np.full(n, PUT_SERVICE)
        service[op_types == OpKind.GET] = GET_CPU
        service[op_types == OpKind.SCAN] = SCAN_CPU
        get_reads = np.zeros(n, dtype=np.int32)
        get_probed = np.zeros(n, dtype=np.int32)
        block_t = (self.device.io_latency
                   + self.device.block_size / self.device.read_bw)

        # Columnar routing: shard (hash/range partition of the keyspace),
        # then region within the (single) shard.  tree = flat shard-major.
        shard_ids = self.router.shard_of(keys) if self.n_shards > 1 \
            else np.zeros(n, np.int64)
        regions = (keys % self.n_regions).astype(np.int64) \
            if self.n_regions > 1 else np.zeros(n, np.int64)
        tree_ids = shard_ids * self.n_regions + regions
        write_mask = (op_types == OpKind.PUT) | (op_types == OpKind.DELETE)
        write_idx = np.nonzero(write_mask)[0]

        # Fill-event schedule: the op index at which each tree's memtable
        # fills = every kpm-th write (PUT or DELETE) routed to that tree.
        fill_events: list[tuple[int, int]] = []  # (op_idx, tree_idx)
        for ti in range(len(self.trees)):
            t_writes = write_idx[tree_ids[write_idx] == ti]
            marks = t_writes[kpm - 1::kpm]
            fill_events.extend((int(m), ti) for m in marks)
        fill_events.sort()
        ev_by_shard: list[list[tuple[int, int]]] = \
            [[] for _ in range(self.n_shards)]
        for op_i, ti in fill_events:
            ev_by_shard[ti // self.n_regions].append((op_i, ti))
        shard_pos = [np.arange(n)] if self.n_shards == 1 else \
            [np.nonzero(shard_ids == s)[0] for s in range(self.n_shards)]
        return _RunState(n=n, op_types=op_types, keys=keys,
                         arrivals=arrivals, scan_lens=scan_lens,
                         service=service, get_reads=get_reads,
                         get_probed=get_probed, block_t=block_t,
                         shard_ids=shard_ids, regions=regions,
                         ev_by_shard=ev_by_shard, shard_pos=shard_pos)

    def _busy_inflation(self, st: "_RunState") -> None:
        """Read service refinement: device busy while compactions run
        (vectorized post-pass over the scheduled job log)."""
        service, arrivals, op_types = st.service, st.arrivals, st.op_types
        get_reads, block_t = st.get_reads, st.block_t
        # Only read kinds are inflated — compute overlap counts at their
        # arrivals alone (a temporal-pass hot path in the fleet engine).
        is_get = op_types == OpKind.GET
        is_scan = op_types == OpKind.SCAN
        ridx = np.nonzero(is_get | is_scan)[0]
        if ridx.size == 0:
            return
        starts = np.sort(np.array([j.t_start for j in self.job_log
                                   if j.kind == "compact"], dtype=np.float64))
        ends = np.sort(np.array([j.t_finish for j in self.job_log
                                 if j.kind == "compact"], dtype=np.float64))
        if starts.size == 0:
            return
        a_r = arrivals[ridx]
        busy_r = (np.searchsorted(starts, a_r, side="right")
                  - np.searchsorted(ends, a_r, side="right"))
        get_r = is_get[ridx]
        gi = ridx[get_r]
        service[gi] += (get_reads[gi] * block_t * (BUSY_ALPHA * busy_r[get_r]))
        if is_scan.any():
            seq_block_t = self.device.block_size / self.device.read_bw
            si = ridx[~get_r]
            service[si] += (get_reads[si] * seq_block_t
                            * (BUSY_ALPHA * busy_r[~get_r]))

    def _make_result(self, st: "_RunState", latency: np.ndarray,
                     makespan: float,
                     stall_events: list[tuple[int, float]] | None = None,
                     job_log: list[Job] | None = None,
                     arrivals: np.ndarray | None = None,
                     chain_counts: list[int] | None = None,
                     chain_stall_s: list[float] | None = None) -> SimResult:
        """Assemble the result.  The overrides exist for the fleet engine,
        whose temporal passes each snapshot their own stall/job ledgers and
        arrival stream while sharing one engine (and its Stats)."""
        if stall_events is None:
            stall_events = self.stall_events
        if job_log is None:
            job_log = self.job_log
        if arrivals is None:
            arrivals = st.arrivals
        if chain_counts is None:
            chain_counts = [len(s.chains) for s in self.shard_stats]
        if chain_stall_s is None:
            chain_stall_s = [sum(c.stall_s for c in s.chains)
                             for s in self.shard_stats]
        stalls = np.array([d for _i, d in stall_events]) \
            if stall_events else np.zeros(0)
        return SimResult(
            arrivals=arrivals, latency=latency, op_types=st.op_types,
            stall_total=float(stalls.sum()),
            stall_max=float(stalls.max()) if stalls.size else 0.0,
            n_stalls=int(stalls.size), stats=self.stats,
            job_log=job_log, makespan=makespan,
            get_reads=st.get_reads, get_probed=st.get_probed,
            shard_ids=st.shard_ids if self.n_shards > 1 else None,
            n_shards=self.n_shards,
            stall_events=stall_events,
            chain_counts=chain_counts,
            chain_stall_s=chain_stall_s,
        )

    def run(self, op_types: np.ndarray, keys: np.ndarray,
            arrivals: np.ndarray,
            scan_lens: np.ndarray | None = None) -> SimResult:
        """Drive the store with a typed op stream (OpKind values).

        ``scan_lens[i]`` is the requested key count of a SCAN op (ignored
        for other kinds; may be omitted for scan-free streams).  Per-kind
        service: PUT/DELETE constant CPU, GET CPU + block reads × device,
        SCAN CPU + per-file seek + blocks spanned × sequential read — all
        read kinds get the same busy-inflation post-pass.
        """
        st = self._setup(op_types, keys, arrivals, scan_lens)
        n = st.n
        op_types, keys, arrivals = st.op_types, st.keys, st.arrivals
        scan_lens, service = st.scan_lens, st.service
        get_reads, get_probed = st.get_reads, st.get_probed
        block_t, regions = st.block_t, st.regions
        ev_by_shard, shard_pos = st.ev_by_shard, st.shard_pos

        # Per-shard processed clocks: D[s] = departure time of shard s's
        # most recently serviced op (exact Lindley per queue, maintained
        # incrementally per window); cur[s] = the shard's op cursor into
        # its own arrival sub-sequence.  Events are processed in
        # SIMULATED-TIME order: each shard's next fill time depends only
        # on its own queue, so one event per shard is staged (advancing
        # that shard's clock) and a heap pops the globally earliest —
        # shared-slot scheduling then sees chronological ready times, so
        # a lagging shard's backlogged jobs cannot phantom-block another
        # shard's earlier device work.  (op_i tiebreak: deterministic.)
        D = [0.0] * self.n_shards
        cur = [0] * self.n_shards
        ptrs = [0] * self.n_shards
        heap: list[tuple[float, int, int, int]] = []

        def stage(s: int) -> None:
            """Advance shard s's clock to its next fill event (applying
            the window structurally) and stage the event for dispatch."""
            if ptrs[s] >= len(ev_by_shard[s]):
                return
            op_i, ti = ev_by_shard[s][ptrs[s]]
            pos = shard_pos[s]
            upper = int(np.searchsorted(pos, op_i, side="right"))
            D[s] = self._advance_clock(s, D[s], pos[cur[s]:upper], op_types,
                                       keys, scan_lens, regions, get_reads,
                                       get_probed, service, arrivals,
                                       block_t)
            cur[s] = upper
            heapq.heappush(heap, (D[s], op_i, s, ti))

        for s in range(self.n_shards):
            stage(s)
        while heap:
            t, op_i, s, ti = heapq.heappop(heap)
            # t = D[s]: the fill happens when its last write is serviced
            if self.sanitizer is not None:
                self.sanitizer.on_event(ti, t)
            tree = self.trees[ti]
            tree.seal_memtable()
            stall = self._wb_stall(ti, t)
            tree.flush_immutable()
            self._schedule_drained(tree, ti, t)
            bg = tree.background_triggers()
            if bg:
                self._schedule_drained(tree, ti, t)
            l0_stall, cid = self._l0_stall(ti, t)
            if l0_stall > stall and cid >= 0:
                # the L0 wait is the binding delay: pin it on the chain
                # whose head clears the awaited slot (the shard's ledger)
                rec = self.shard_stats[s].chain_index.get(cid)
                if rec is not None:
                    rec.stall_s += l0_stall
            stall = max(stall, l0_stall)
            if stall > 0:
                service[op_i] += stall
                D[s] += stall
                self.stall_events.append((op_i, stall))
            ptrs[s] += 1
            stage(s)
        for s in range(self.n_shards):
            self._advance_clock(s, D[s], shard_pos[s][cur[s]:], op_types,
                                keys, scan_lens, regions, get_reads,
                                get_probed, service, arrivals, block_t)

        # --- read service refinement: device busy while compactions run ----
        self._busy_inflation(st)

        # --- exact Lindley over each shard's FIFO queue --------------------
        # (one queue = the legacy single-queue recursion, bit for bit)
        latency = np.zeros(n, np.float64)
        makespan = 0.0
        for s in range(self.n_shards):
            pos = shard_pos[s]
            if pos.shape[0] == 0:
                continue
            S = np.cumsum(service[pos])
            base = arrivals[pos].astype(np.float64).copy()
            base[1:] -= S[:-1]
            departures = S + np.maximum.accumulate(base)
            latency[pos] = departures - arrivals[pos]
            makespan = max(makespan, float(departures[-1]))
        return self._make_result(st, latency, makespan)

    def serve(self, spec, *, load_factor: float = 1.0):
        """Drive the store from a ``TrafficSpec`` (open-loop serving).

        The serving layer (``repro.serving.traffic``) materializes the
        spec's tenants into one interleaved arrival schedule, runs the
        admission pre-pass, and feeds the admitted stream through
        :meth:`run` — so ``FleetEngine`` inherits this entry point and
        both engines accept the same spec.  With admission disabled the
        result is byte-identical to :meth:`run` on the materialized
        arrays (the closed↔open parity gate).  Returns a
        ``ServeResult`` (per-tenant ledgers, goodput, SLO accounting).
        """
        # function-scoped: serving sits above core in the layer order
        from ..serving.traffic import serve as _serve
        return _serve(self, spec, load_factor=load_factor)

    # ------------------------------------------------------------------
    def _advance_clock(self, shard: int, D: float, idx: np.ndarray,
                       op_types, keys, scan_lens, regions, get_reads,
                       get_probed, service, arrivals,
                       block_t: float) -> float:
        """Apply shard ``shard``'s ops at global indices ``idx`` (its next
        arrival-order window) structurally and advance its processed clock.

        Returns the departure time of the window's last op (before any
        stall injection).  Each region's window slice becomes ONE typed
        ``RequestBatch`` through ``LSMTree.apply_batch`` (writes land
        first, then the window's GETs/SCANs observe constant tree state —
        trees are independent, so per-tree application equals global
        writes-then-reads order).  Read service includes the base
        device-read cost here; the busy-inflation term is refined in a
        vectorized post-pass.
        """
        if idx.shape[0] == 0:
            return D
        wsum, wmax = self._advance_window(shard, idx, op_types, keys,
                                          scan_lens, regions, get_reads,
                                          get_probed, service, arrivals,
                                          block_t)
        return wsum + max(D, wmax)

    def _advance_window(self, shard: int, idx: np.ndarray,
                        op_types, keys, scan_lens, regions, get_reads,
                        get_probed, service, arrivals,
                        block_t: float) -> tuple[float, float]:
        """The structural body of :meth:`_advance_clock`: apply the window
        to the shard's trees, charge read service, and return the window's
        Lindley aggregates ``(wsum, wmax)`` — total service and
        ``max_k(a_k - S_{k-1})`` — from which ANY carried-in clock advances
        as ``D' = wsum + max(D, wmax)``.  The fleet engine records these
        per window in its structural phase so its temporal phase replays
        clock advances in O(1) per event."""
        self._apply_window(shard, idx, op_types, keys, scan_lens, regions,
                           get_reads, get_probed, service, block_t)
        # incremental Lindley: D_j = S_j + max(D_prev, max_k(a_k - S_{k-1}))
        s = service[idx].astype(np.float64)
        s_cum = np.cumsum(s)
        a = arrivals[idx].astype(np.float64)
        shifted = np.empty_like(s_cum)
        shifted[0] = 0.0
        shifted[1:] = s_cum[:-1]
        return float(s_cum[-1]), float(np.max(a - shifted))

    def _apply_window(self, shard: int, idx: np.ndarray,
                      op_types, keys, scan_lens, regions, get_reads,
                      get_probed, service, block_t: float) -> None:
        """Arrival-independent half of :meth:`_advance_window`: apply the
        window's ops to the shard's trees and charge base read service.
        Windows are op-index-defined and stall injection only ever touches
        the last op of an already-aggregated window, so everything here —
        tree evolution, ``service`` base values, read counters — is the
        same for every arrival stream over the same op stream.  The fleet
        engine exploits exactly that: one structural replay amortized over
        a whole arrival-rate axis."""
        w_types = op_types[idx]
        w_keys = keys[idx]
        w_lens = scan_lens[idx]
        w_regions = regions[idx]
        stats = self.shard_stats[shard]
        tree_base = shard * self.n_regions
        scan_delivered = np.zeros(w_types.shape[0], np.int64)
        has_reads = bool(((w_types == OpKind.GET)
                          | (w_types == OpKind.SCAN)).any())
        for r in range(self.n_regions):
            rm = w_regions == r if self.n_regions > 1 \
                else np.ones(w_types.shape[0], bool)
            if not rm.any():
                continue
            ri = np.nonzero(rm)[0]
            if not has_reads:
                # Write-only window (the fillrandom hot path): skip the
                # batch machinery, same array-order semantics.
                self.trees[tree_base + r]._write_batch(
                    w_keys[ri], w_types[ri] == OpKind.DELETE)
                continue
            res = self.trees[tree_base + r].apply_batch(
                RequestBatch(w_types[ri], w_keys[ri], w_lens[ri]))
            is_get = res.kinds == OpKind.GET
            is_scan = res.kinds == OpKind.SCAN
            if is_get.any() or is_scan.any():
                rd = np.nonzero(is_get | is_scan)[0]
                get_reads[idx[ri[rd]]] = res.reads[rd]
                get_probed[idx[ri[rd]]] = res.probed[rd]
            if is_get.any():
                stats.device_reads += int(res.reads[is_get].sum())
                stats.ops += int(is_get.sum())
            if is_scan.any():
                sc = np.nonzero(is_scan)[0]
                scan_delivered[ri[sc]] = res.seqs[sc]
                stats.scan_blocks += int(res.reads[is_scan].sum())
                stats.scan_ops += int(is_scan.sum())
                stats.ops += int(is_scan.sum())
        g_idx = idx[w_types == OpKind.GET]
        service[g_idx] += get_reads[g_idx] * block_t
        w_sc = np.nonzero(w_types == OpKind.SCAN)[0]
        if w_sc.shape[0]:
            s_idx = idx[w_sc]
            # Modern-iterator latency model: the per-level/per-L0-file
            # seeks are issued CONCURRENTLY (RocksDB async_io-style, NVMe
            # queue depth), so a scan pays ONE seek wave of io_latency,
            # then streams its delivered bytes at sequential bandwidth,
            # plus a small per-file iterator CPU term.  The per-file block
            # traffic (get_reads) still hits the device — it feeds busy
            # inflation and Stats.scan_blocks — but it is not serialized
            # into foreground latency.
            delivered = scan_delivered[w_sc] * float(self.cfg.kv_size)
            service[s_idx] += (self.device.io_latency
                               + delivered / self.device.read_bw
                               + get_probed[s_idx] * SCAN_FILE_CPU)

"""vLSM core: the paper's contribution (compaction-chain-aware LSM KV store).

Public API::

    from repro.core import (LSMConfig, DeviceModel, LSMTree, Simulator,
                            OpKind, RequestBatch, ResultBatch,
                            CompactionPolicy, get_policy, policies)

``LSMTree.apply_batch(RequestBatch) -> ResultBatch`` is the single typed
operation entry point (PUT/GET/DELETE/SCAN); ``put_batch`` / ``get_batch``
/ ``delete_batch`` / ``scan_batch`` are thin wrappers over it.

Compaction behaviour is a registry-backed strategy layer
(:mod:`repro.core.policies`): ``LSMConfig.policy`` names a registered
``CompactionPolicy`` and the mechanism (``LSMTree``/``Simulator``) never
branches on it.  The legacy ``Policy`` str-enum survives as aliases for
the five seed policy names.
"""

from . import policies
from .fleet import (FleetEngine, PendingRun, SweepPoint, fleet_sweep,
                    reset_uid_counters, serial_sweep, traffic_curve)
from .level_index import LevelIndex
from .lsm import Job, LSMTree
from .memtable import Memtable
from .policies import CompactionPolicy, get_policy
from .shard import ShardRouter, ShardedStore
from .sim import SimResult, Simulator
from .sst import SST
from .stats import ChainRecord, FleetStats, Stats, TenantLedger
from .types import (DeviceModel, LSMConfig, OpKind, Policy, RequestBatch,
                    ResultBatch)

__all__ = [
    "ChainRecord", "CompactionPolicy", "DeviceModel", "FleetEngine",
    "FleetStats", "Job", "LSMConfig", "LSMTree", "LevelIndex", "Memtable",
    "OpKind", "PendingRun", "Policy", "RequestBatch", "ResultBatch", "SST",
    "ShardRouter", "ShardedStore", "SimResult", "Simulator", "Stats",
    "SweepPoint", "TenantLedger", "fleet_sweep", "get_policy", "policies",
    "reset_uid_counters", "serial_sweep", "traffic_curve",
]

"""vLSM core: the paper's contribution (compaction-chain-aware LSM KV store).

Public API::

    from repro.core import (LSMConfig, DeviceModel, LSMTree, Simulator,
                            OpKind, RequestBatch, ResultBatch,
                            CompactionPolicy, get_policy, policies)

``LSMTree.apply_batch(RequestBatch) -> ResultBatch`` is the single typed
operation entry point (PUT/GET/DELETE/SCAN); ``put_batch`` / ``get_batch``
/ ``delete_batch`` / ``scan_batch`` are thin wrappers over it.

Compaction behaviour is a registry-backed strategy layer
(:mod:`repro.core.policies`): ``LSMConfig.policy`` names a registered
``CompactionPolicy`` and the mechanism (``LSMTree``/``Simulator``) never
branches on it.  The legacy ``Policy`` str-enum survives as aliases for
the five seed policy names.
"""

from . import policies
from .fleet import (FleetEngine, PendingRun, SweepPoint, fleet_sweep,
                    reset_uid_counters, serial_sweep, traffic_curve)
from .level_index import LevelIndex
from .lsm import Job, LSMTree
from .memtable import Memtable
from .policies import CompactionPolicy, get_policy
from .shard import ShardRouter, ShardedStore
from .sim import SimResult, Simulator
from .sst import SST
from .stats import ChainRecord, FleetStats, Stats, TenantLedger
from .sweeps import (DEFAULT_CACHE, LEDGER, ExecutorLedger, PointTiming,
                     StructuralCache, parallel_map, point_key, run_point,
                     serial_sweep_parallel, sweep_execute)
from .types import (DeviceModel, LSMConfig, OpKind, Policy, RequestBatch,
                    ResultBatch)
from .uids import UidNamespace

__all__ = [
    "ChainRecord", "CompactionPolicy", "DEFAULT_CACHE", "DeviceModel",
    "ExecutorLedger", "FleetEngine", "FleetStats", "Job", "LEDGER",
    "LSMConfig", "LSMTree", "LevelIndex", "Memtable", "OpKind",
    "PendingRun", "PointTiming", "Policy", "RequestBatch", "ResultBatch",
    "SST", "ShardRouter", "ShardedStore", "SimResult", "Simulator",
    "Stats", "StructuralCache", "SweepPoint", "TenantLedger",
    "UidNamespace", "fleet_sweep", "get_policy", "parallel_map",
    "point_key", "policies", "reset_uid_counters", "run_point",
    "serial_sweep", "serial_sweep_parallel", "sweep_execute",
    "traffic_curve",
]

"""vLSM core: the paper's contribution (compaction-chain-aware LSM KV store).

Public API::

    from repro.core import LSMConfig, Policy, DeviceModel, LSMTree, Simulator
"""

from .level_index import LevelIndex
from .lsm import Job, LSMTree
from .memtable import Memtable
from .sim import SimResult, Simulator
from .sst import SST
from .stats import ChainRecord, Stats
from .types import DeviceModel, LSMConfig, Policy

__all__ = [
    "ChainRecord", "DeviceModel", "Job", "LSMConfig", "LSMTree",
    "LevelIndex", "Memtable", "Policy", "SST", "SimResult", "Simulator",
    "Stats",
]

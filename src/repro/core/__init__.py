"""vLSM core: the paper's contribution (compaction-chain-aware LSM KV store).

Public API::

    from repro.core import (LSMConfig, Policy, DeviceModel, LSMTree,
                            Simulator, OpKind, RequestBatch, ResultBatch)

``LSMTree.apply_batch(RequestBatch) -> ResultBatch`` is the single typed
operation entry point (PUT/GET/DELETE/SCAN); ``put_batch`` / ``get_batch``
/ ``delete_batch`` / ``scan_batch`` are thin wrappers over it.
"""

from .level_index import LevelIndex
from .lsm import Job, LSMTree
from .memtable import Memtable
from .sim import SimResult, Simulator
from .sst import SST
from .stats import ChainRecord, Stats
from .types import (DeviceModel, LSMConfig, OpKind, Policy, RequestBatch,
                    ResultBatch)

__all__ = [
    "ChainRecord", "DeviceModel", "Job", "LSMConfig", "LSMTree",
    "LevelIndex", "Memtable", "OpKind", "Policy", "RequestBatch",
    "ResultBatch", "SST", "SimResult", "Simulator", "Stats",
]

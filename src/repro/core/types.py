"""Core types of the vLSM store: the typed operation API and configuration.

Two groups live here:

* **The operation surface** — :class:`OpKind` (PUT/GET/DELETE/SCAN), the
  columnar :class:`RequestBatch` (kinds / keys / scan_lens / seqnos as flat
  numpy arrays) and :class:`ResultBatch`.  ``LSMTree.apply_batch`` is the
  single entry point; every harness (Simulator, YCSB, db_bench) routes
  through one batch type instead of four parallel array conventions.

* **Configuration dataclasses** — all sizes in *bytes*.  The paper's
  defaults (RocksDB-style) are encoded in :func:`LSMConfig.rocksdb_default`;
  the vLSM configuration of §4/§5 in :func:`LSMConfig.vlsm_default`.
  Benchmarks scale the absolute sizes down (the container is laptop-scale)
  while preserving every ratio the paper's analysis depends on:
  ``memtable == S_M``, ``L1 = f * S_M`` (vLSM) or ``L1 = L0`` (RocksDB),
  growth factor ``f`` across levels, and the larger ``phi`` between L1 and
  L2 for vLSM.

Tombstone encoding
------------------

DELETE writes a *tombstone*: a normal (key, seq) entry whose seqno carries a
tag bit — ``enc = (seq << 1) | is_tombstone``.  Because logical seqnos are
globally unique and increasing, the encoding is monotone in ``seq``
regardless of the tag, so every latest-wins merge path (numpy / jnp / the
Pallas merge-path kernel) works on encoded seqnos unchanged.  Markers flow
memtable → SST → compactions and are dropped only when a merge writes the
bottom level; :func:`seq_decode` strips the tag at every user-visible
boundary (GET/SCAN results, ``merged_view``).
"""

from __future__ import annotations

import dataclasses
import enum
import os
from dataclasses import dataclass, field

import numpy as np


def _paranoid_default() -> bool:
    """Default for ``LSMConfig.paranoid_checks``: the test suite turns it
    on via ``REPRO_PARANOID_CHECKS=1`` (tests/conftest.py); benchmarks and
    production paths leave it off."""
    return os.environ.get("REPRO_PARANOID_CHECKS", "0") == "1"


class OpKind(enum.IntEnum):
    """Typed KV operations.  PUT/GET keep the legacy 0/1 wire values."""

    PUT = 0
    GET = 1
    DELETE = 2
    SCAN = 3


def seq_encode(seqs: np.ndarray, tombstone) -> np.ndarray:
    """Tag logical seqnos with the tombstone bit (monotone in ``seqs``)."""
    return (np.asarray(seqs, np.int64) << 1) | np.asarray(tombstone, np.int64)


def seq_decode(enc: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split encoded seqnos into ``(logical_seq, is_tombstone)``."""
    enc = np.asarray(enc, np.int64)
    return enc >> 1, (enc & 1).astype(bool)


@dataclass
class RequestBatch:
    """A columnar batch of typed KV operations (the store's request ABI).

    ``kinds[i]`` is an :class:`OpKind` value; ``keys[i]`` is the op's key
    (a SCAN's *start* key); ``scan_lens[i]`` is the number of live keys a
    SCAN returns (0 for other kinds); ``seqnos[i]`` is the logical seqno
    the engine assigned to a PUT/DELETE (-1 until applied).
    """

    kinds: np.ndarray                       # uint8, OpKind values
    keys: np.ndarray                        # int64
    scan_lens: np.ndarray | None = None     # int32; lazily zeros
    seqnos: np.ndarray | None = None        # int64; lazily -1

    def __post_init__(self) -> None:
        self.kinds = np.ascontiguousarray(self.kinds, np.uint8)
        self.keys = np.ascontiguousarray(self.keys, np.int64)
        n = self.kinds.shape[0]
        assert self.keys.shape[0] == n, "kinds/keys length mismatch"
        if self.scan_lens is None:
            self.scan_lens = np.zeros(n, np.int32)
        else:
            self.scan_lens = np.ascontiguousarray(self.scan_lens, np.int32)
            assert self.scan_lens.shape[0] == n
        if self.seqnos is None:
            self.seqnos = np.full(n, -1, np.int64)
        else:
            self.seqnos = np.ascontiguousarray(self.seqnos, np.int64)
            assert self.seqnos.shape[0] == n
        scans = self.kinds == OpKind.SCAN
        assert (self.scan_lens[scans] > 0).all(), "SCAN needs scan_lens > 0"

    def __len__(self) -> int:
        return int(self.kinds.shape[0])

    def mask(self, *kinds: OpKind) -> np.ndarray:
        m = np.zeros(len(self), bool)
        for k in kinds:
            m |= self.kinds == k
        return m

    # --- constructors -----------------------------------------------------
    @staticmethod
    def puts(keys: np.ndarray) -> "RequestBatch":
        keys = np.asarray(keys, np.int64)
        return RequestBatch(np.full(keys.shape[0], OpKind.PUT, np.uint8), keys)

    @staticmethod
    def gets(keys: np.ndarray) -> "RequestBatch":
        keys = np.asarray(keys, np.int64)
        return RequestBatch(np.full(keys.shape[0], OpKind.GET, np.uint8), keys)

    @staticmethod
    def deletes(keys: np.ndarray) -> "RequestBatch":
        keys = np.asarray(keys, np.int64)
        return RequestBatch(np.full(keys.shape[0], OpKind.DELETE, np.uint8),
                            keys)

    @staticmethod
    def scans(start_keys: np.ndarray, lengths: np.ndarray) -> "RequestBatch":
        start_keys = np.asarray(start_keys, np.int64)
        return RequestBatch(
            np.full(start_keys.shape[0], OpKind.SCAN, np.uint8),
            start_keys, scan_lens=np.asarray(lengths, np.int32))


@dataclass
class ResultBatch:
    """Aligned, columnar results for one :class:`RequestBatch`.

    ``seqs[i]``: PUT/DELETE → the assigned logical seqno; GET → the found
    logical seqno or -1 (missing *or deleted*); SCAN → number of live keys
    returned.  ``reads``/``probed`` are device block reads and SSTs touched
    (nonzero only for read kinds).  SCAN payloads are flattened into
    ``scan_keys``/``scan_seqs``; op *i* owns the half-open slice
    ``scan_offsets[i]:scan_offsets[i+1]`` (zero-width for non-scans).
    """

    kinds: np.ndarray
    seqs: np.ndarray
    reads: np.ndarray
    probed: np.ndarray
    scan_offsets: np.ndarray = field(
        default_factory=lambda: np.zeros(1, np.int64))
    scan_keys: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))
    scan_seqs: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))

    def __len__(self) -> int:
        return int(self.kinds.shape[0])

    def scan_slice(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(keys, logical seqs) returned by op ``i`` (empty for non-scans)."""
        a, b = int(self.scan_offsets[i]), int(self.scan_offsets[i + 1])
        return self.scan_keys[a:b], self.scan_seqs[a:b]


class Policy(str, enum.Enum):
    """Legacy aliases for the five seed compaction policies (Fig. 3).

    The compaction surface is now the registry-backed strategy layer in
    :mod:`repro.core.policies`; ``LSMConfig.policy`` carries a plain
    registry *name* string, and this str-enum survives only for backwards
    compatibility (its members compare equal to the name strings, so
    ``cfg.policy == Policy.VLSM`` keeps working).  New policies — e.g. the
    lazy-leveling ``"lazy"`` policy — register a name without touching
    this enum.
    """

    VLSM = "vlsm"            # Fig 3(d): no tiering, small SSTs, phi, vSSTs
    ROCKSDB = "rocksdb"      # Fig 3(b): tiering L0 + leveled rest + debt
    ROCKSDB_IO = "rocksdb_io"  # RocksDB with overflow (debt) disabled
    ADOC = "adoc"            # Fig 3(c): tiering + debt + aggressive scheduling
    LSMI = "lsmi"            # Fig 3(a): incremental, no tiering, fixed SSTs


@dataclass(frozen=True)
class DeviceModel:
    """Deterministic storage-device model (replaces the paper's NVMe).

    The reproduction target is *trends* (P99 ratios, stall shares, I/O
    amplification), not absolute seconds, so a bandwidth/latency model is
    sufficient and keeps the discrete-event simulation exact and replayable.
    Defaults approximate the paper's Samsung 970 EVO Plus.
    """

    write_bw: float = 2.0e9       # sequential write bytes/s
    read_bw: float = 3.5e9        # sequential read bytes/s
    io_latency: float = 100e-6    # per-I/O setup latency (seconds)
    block_size: int = 4096        # read granularity for point lookups
    compaction_slots: int = 4     # background compaction/flush threads

    def write_time(self, nbytes: int, n_ios: int = 1) -> float:
        return nbytes / self.write_bw + n_ios * self.io_latency

    def read_time(self, nbytes: int, n_ios: int = 1) -> float:
        return nbytes / self.read_bw + n_ios * self.io_latency

    @staticmethod
    def scaled(lam: float) -> "DeviceModel":
        """Device matched to a data scale ``lam = scale_bytes / 64 MiB``.

        Bandwidth scales with the data while per-IO latency stays constant,
        so a λ-scaled SST transfers in exactly the time a full-size SST
        takes on the paper's NVMe — wall-clock stall magnitudes and the
        seek-vs-transfer balance match the paper at every SST size.
        """
        return DeviceModel(write_bw=2.0e9 * lam, read_bw=3.5e9 * lam)


@dataclass(frozen=True)
class LSMConfig:
    # --- data shape -------------------------------------------------------
    kv_size: int = 200                  # bytes per KV pair (paper §5: 200 B)
    # --- memory component -------------------------------------------------
    memtable_size: int = 1 << 20        # bytes; == SST size, as in the paper
    max_write_buffers: int = 2          # active + immutable (RocksDB default)
    # --- on-device layout -------------------------------------------------
    sst_size: int = 1 << 20             # S_M, the fixed SST size
    l0_max_ssts: int = 4                # L0 compaction trigger (RocksDB: 4)
    l0_stop_ssts: int = 8               # hard write-stop L0 file count
    growth_factor: int = 8              # f across levels
    phi: int = 32                       # vLSM growth factor L1 -> L2
    max_levels: int = 5                 # L0..L4
    # --- policy -----------------------------------------------------------
    # Registry name of the compaction policy (repro.core.policies); legacy
    # ``Policy`` enum members are accepted and normalized to their value.
    policy: str = "vlsm"
    debt_factor: float = 0.0            # allowed overflow fraction per level
                                        # (rocksdb: 0.25, adoc: 1.0, *_io: 0)
    adoc_batch: int = 4                 # SSTs per compaction job under ADOC
    # --- vSST policy (§4.2) -----------------------------------------------
    vsst_min_frac: float | None = None  # S_m = S_M * frac; default 1/f
    # --- lookup model -----------------------------------------------------
    bloom_fpr: float = 0.01             # bloom-filter false-positive rate
    block_size: int = 4096              # device read granularity for scans
                                        # (mirrors DeviceModel.block_size)
    # LevelIndex rank backend: None follows repro.core.level_index's module
    # switch (numpy by default); "jnp" / "pallas" pin this store's manifest
    # queries to the array backends (parity-tested drop-ins).
    index_backend: str | None = None
    # --- sharding (repro.core.shard) --------------------------------------
    # Number of independent per-shard LSM trees the keyspace is partitioned
    # over.  1 = the single-tree engine (byte-identical to the pre-sharding
    # code); N > 1 = ShardedStore routing + per-shard foreground queues in
    # the DES, all contending for ONE shared DeviceModel.
    n_shards: int = 1
    # Keyspace partitioner: "hash" (splitmix64 key mix, the default — load
    # spreads but ranges scatter) or "range" (contiguous key stripes over
    # [0, shard_key_space) — range-friendly, skew-prone).
    shard_router: str = "hash"
    # Upper bound of the key domain the range router stripes (the hash
    # router ignores it).  Matches bench_kv.workloads.KEYSPACE.
    shard_key_space: int = 1 << 48
    # Chain-aware background scheduling: the DES's compaction pool orders
    # each drained batch by chain-head urgency (L0-pressure-relieving
    # chains first — RocksDB low-pri semantics; the policy object's
    # chain_priority hook refines the order).  False restores the legacy
    # FIFO drain order.  Either way, structure is eager and identical —
    # only device timing (and hence latency/stalls) differs.
    chain_aware_sched: bool = True
    # Run LSMTree.check_invariants() (mechanism + policy invariants) on
    # every drain_jobs() — continuous validation for CI; leave off in
    # benchmarks (tests/conftest.py flips the env default on).
    paranoid_checks: bool = field(default_factory=_paranoid_default)

    def __post_init__(self) -> None:
        # normalize legacy Policy enum members to their registry name
        object.__setattr__(self, "policy",
                           getattr(self.policy, "value", self.policy))
        assert self.n_shards >= 1, "n_shards must be >= 1"
        assert self.shard_router in ("hash", "range"), \
            f"unknown shard_router {self.shard_router!r} (hash|range)"

    # ----------------------------------------------------------------------
    @property
    def s_m(self) -> int:
        """Minimum vSST size S_m (paper: S_M / f)."""
        frac = self.vsst_min_frac if self.vsst_min_frac is not None else 1.0 / self.growth_factor
        return max(self.kv_size, int(self.sst_size * frac))

    @property
    def s_M(self) -> int:
        return self.sst_size

    @property
    def keys_per_sst(self) -> int:
        return max(1, self.sst_size // self.kv_size)

    @property
    def keys_per_memtable(self) -> int:
        return max(1, self.memtable_size // self.kv_size)

    def compaction_policy(self):
        """The registry-resolved CompactionPolicy strategy object."""
        from .policies import get_policy  # lazy: policies import this module
        return get_policy(self.policy)

    @property
    def tiering(self) -> bool:
        """Does L0 use a tiering compaction step (RocksDB-family designs)?"""
        return self.compaction_policy().tiering_l0

    def level_target(self, level: int) -> int:
        """Target size in bytes for a leveled level (level >= 1) — the
        policy object owns the sizing rule."""
        return self.compaction_policy().level_target(self, level)

    def level_limit(self, level: int) -> int:
        """Hard limit including compaction debt (overflow)."""
        return self.compaction_policy().level_limit(self, level)

    def with_(self, **kw) -> "LSMConfig":
        return dataclasses.replace(self, **kw)

    # --- canned configurations -------------------------------------------
    # Thin delegates to registry["name"].default_config(); kept as the
    # stable convenience surface.
    @staticmethod
    def rocksdb_default(scale: int = 1 << 20) -> "LSMConfig":
        """RocksDB defaults at a byte `scale` standing in for 64 MB."""
        from .policies import get_policy
        return get_policy("rocksdb").default_config(scale)

    @staticmethod
    def rocksdb_io_default(scale: int = 1 << 20) -> "LSMConfig":
        from .policies import get_policy
        return get_policy("rocksdb_io").default_config(scale)

    @staticmethod
    def adoc_default(scale: int = 1 << 20) -> "LSMConfig":
        from .policies import get_policy
        return get_policy("adoc").default_config(scale)

    @staticmethod
    def vlsm_default(scale: int = 1 << 20, sst_frac: int = 8) -> "LSMConfig":
        """vLSM §5 defaults: SSTs S_M = scale/sst_frac (8 MB when scale=64 MB),
        memtable == S_M, L1 = f*S_M, phi = L0_rocksdb_equivalent/L1 ratio 32."""
        from .policies import get_policy
        return get_policy("vlsm").default_config(scale, sst_frac=sst_frac)

    @staticmethod
    def lsmi_default(scale: int = 1 << 20) -> "LSMConfig":
        from .policies import get_policy
        return get_policy("lsmi").default_config(scale)

"""Single-step decode (``serve_step``) + cache constructors for all families.

The decode path is what the ``decode_32k`` / ``long_500k`` shapes lower:
one new token against a cache of ``max_seq``.  Cache layout is scan-stacked
([L, B, ...]) so the layer loop stays a single compiled body.

``init_cache`` builds the zeroed cache pytree; the launch layer calls it
under ``jax.eval_shape`` for the dry-run (no allocation) and for real in
the serving example.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssd as ssd_mod
from .blocks import _layer_meta
from .common import dtype_of, norm, scan_layers


# ============================================================= cache build
def init_cache(cfg, batch: int, max_seq: int):
    dt = dtype_of(cfg)
    n_scan = cfg.n_layers - cfg.first_dense_layers
    if cfg.family in ("ssm", "hybrid"):
        cache = {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1,
                               ssd_mod.conv_dim(cfg)), dt),
            "state": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                                cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            "pos": jnp.zeros((1,), jnp.int32),
        }
        if cfg.attn_every:
            n_apps = len(range(cfg.attn_every, cfg.n_layers, cfg.attn_every))
            cache["attn_k"] = jnp.zeros(
                (n_apps, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt)
            cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
        return cache
    if cfg.family == "encdec":
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                  cfg.n_kv_heads, cfg.head_dim), dt),
            "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                  cfg.n_kv_heads, cfg.head_dim), dt),
            "pos": jnp.zeros((1,), jnp.int32),
        }
    if cfg.attn_kind == "mla":
        cache = {
            "ckv": jnp.zeros((n_scan, batch, max_seq, cfg.kv_lora_rank), dt),
            "kr": jnp.zeros((n_scan, batch, max_seq, cfg.qk_rope_dim), dt),
            "pos": jnp.zeros((1,), jnp.int32),
        }
        if cfg.first_dense_layers:
            cache["d_ckv"] = jnp.zeros(
                (cfg.first_dense_layers, batch, max_seq, cfg.kv_lora_rank), dt)
            cache["d_kr"] = jnp.zeros(
                (cfg.first_dense_layers, batch, max_seq, cfg.qk_rope_dim), dt)
        return cache
    return {
        "k": jnp.zeros((n_scan, batch, max_seq, cfg.n_kv_heads,
                        cfg.head_dim), dt),
        "v": jnp.zeros((n_scan, batch, max_seq, cfg.n_kv_heads,
                        cfg.head_dim), dt),
        "pos": jnp.zeros((1,), jnp.int32),
    }


# ============================================================== decode step
def decode_step(cfg, params, tokens, pos, cache, *, batch_extras=None,
                absorbed_mla: bool = True):
    """tokens: [B, 1] int32; pos: [B] int32 write index; cache: pytree.
    Returns (logits [B, 1, V], new_cache)."""
    if cfg.family in ("ssm", "hybrid"):
        return _decode_ssm(cfg, params, tokens, pos, cache)
    if cfg.family == "encdec":
        return _decode_encdec(cfg, params, tokens, pos, cache)
    return _decode_decoder(cfg, params, tokens, pos, cache,
                           absorbed_mla=absorbed_mla)


def _mlp_step(cfg, lp, h, dense: bool):
    m_in = norm(cfg, h, lp["mlp_norm"])
    if cfg.mlp_kind == "moe" and not dense:
        m_out, _aux = moe_mod.moe_forward(cfg, lp["mlp"], m_in)
    else:
        m_out = moe_mod.mlp_forward(cfg, lp["mlp"], m_in)
    if cfg.post_norm:
        m_out = norm(cfg, m_out, lp["post_mlp_norm"])
    return h + m_out


def _decode_decoder(cfg, params, tokens, pos, cache, *, absorbed_mla):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    thetas, windows, d_thetas, d_windows, _ = _layer_meta(cfg)
    new_cache = dict(cache)

    for i in range(cfg.first_dense_layers):
        lp = params[f"dense{i}"]
        a_in = norm(cfg, h, lp["attn_norm"])
        if cfg.attn_kind == "mla":
            a_out, ckv, kr = mla_mod.mla_decode(
                cfg, lp["attn"], a_in, pos, cache["d_ckv"][i],
                cache["d_kr"][i], absorbed=absorbed_mla)
            new_cache["d_ckv"] = new_cache["d_ckv"].at[i].set(ckv)
            new_cache["d_kr"] = new_cache["d_kr"].at[i].set(kr)
        else:
            a_out, k, v = attn.attn_decode(
                cfg, lp["attn"], a_in, pos, d_thetas[i], d_windows[i],
                cache["k"][i], cache["v"][i])
            new_cache["k"] = new_cache["k"].at[i].set(k)
            new_cache["v"] = new_cache["v"].at[i].set(v)
        if cfg.post_norm:
            a_out = norm(cfg, a_out, lp["post_attn_norm"])
        h = _mlp_step(cfg, lp, h + a_out, dense=True)

    def body(h, xs):
        if cfg.attn_kind == "mla":
            lp, theta, window, ckv, kr = xs
            a_in = norm(cfg, h, lp["attn_norm"])
            a_out, ckv, kr = mla_mod.mla_decode(cfg, lp["attn"], a_in, pos,
                                                ckv, kr, absorbed=absorbed_mla)
            kv_out = (ckv, kr)
        else:
            lp, theta, window, k, v = xs
            a_in = norm(cfg, h, lp["attn_norm"])
            a_out, k, v = attn.attn_decode(cfg, lp["attn"], a_in, pos, theta,
                                           window, k, v)
            kv_out = (k, v)
        if cfg.post_norm:
            a_out = norm(cfg, a_out, lp["post_attn_norm"])
        h = _mlp_step(cfg, lp, h + a_out, dense=False)
        return h, kv_out

    if cfg.attn_kind == "mla":
        xs = (params["layers"], thetas, windows, cache["ckv"], cache["kr"])
        h, (ckv, kr) = scan_layers(body, h, xs)
        new_cache["ckv"], new_cache["kr"] = ckv, kr
    else:
        xs = (params["layers"], thetas, windows, cache["k"], cache["v"])
        h, (k, v) = scan_layers(body, h, xs)
        new_cache["k"], new_cache["v"] = k, v

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    new_cache["pos"] = cache["pos"] + 1
    return h @ head, new_cache


def _decode_ssm(cfg, params, tokens, pos, cache):
    h = jnp.take(params["embed"], tokens, axis=0)
    new_cache = dict(cache)

    def body(h, xs):
        lp, conv, state = xs
        a_in = norm(cfg, h, lp["norm"])
        out, conv, state = ssd_mod.ssd_decode(cfg, lp["ssd"], a_in, conv, state)
        return h + out, (conv, state)

    if cfg.attn_every:
        n = cfg.n_layers
        convs, states = [], []
        app = 0
        for seg_start in range(0, n, cfg.attn_every):
            seg_end = min(seg_start + cfg.attn_every, n)
            seg = jax.tree.map(lambda x: x[seg_start:seg_end], params["layers"])
            xs = (seg, cache["conv"][seg_start:seg_end],
                  cache["state"][seg_start:seg_end])
            h, (conv, state) = scan_layers(body, h, xs)
            convs.append(conv)
            states.append(state)
            if seg_end < n:
                lp = params["shared_attn"]
                a_in = norm(cfg, h, lp["attn_norm"])
                a_out, k, v = attn.attn_decode(
                    cfg, lp["attn"], a_in, pos, cfg.rope_theta, jnp.int32(-1),
                    cache["attn_k"][app], cache["attn_v"][app])
                new_cache["attn_k"] = new_cache["attn_k"].at[app].set(k)
                new_cache["attn_v"] = new_cache["attn_v"].at[app].set(v)
                h = h + a_out
                m_in = norm(cfg, h, lp["mlp_norm"])
                h = h + moe_mod.mlp_forward(cfg, lp["mlp"], m_in)
                app += 1
        new_cache["conv"] = jnp.concatenate(convs, axis=0)
        new_cache["state"] = jnp.concatenate(states, axis=0)
    else:
        xs = (params["layers"], cache["conv"], cache["state"])
        h, (conv, state) = scan_layers(body, h, xs)
        new_cache["conv"], new_cache["state"] = conv, state

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    new_cache["pos"] = cache["pos"] + 1
    return h @ head, new_cache


def _decode_encdec(cfg, params, tokens, pos, cache):
    from .blocks import _cross_attn
    h = jnp.take(params["embed"], tokens, axis=0)
    from .common import sinusoidal_positions
    pe = sinusoidal_positions(int(cache["k"].shape[2]), cfg.d_model)
    h = h + pe[pos][:, None, :].astype(h.dtype)

    def body(h, xs):
        lp, k, v, ck, cv = xs
        a_in = norm(cfg, h, lp["attn_norm"])
        a_out, k, v = attn.attn_decode(cfg, lp["attn"], a_in, pos,
                                       cfg.rope_theta, jnp.int32(-1), k, v)
        h = h + a_out
        c_in = norm(cfg, h, lp["cross_norm"])
        h = h + _cross_attn(cfg, lp["cross"], c_in, ck, cv)
        m_in = norm(cfg, h, lp["mlp_norm"])
        h = h + moe_mod.mlp_forward(cfg, lp["mlp"], m_in)
        return h, (k, v)

    xs = (params["layers"], cache["k"], cache["v"],
          cache["cross_k"], cache["cross_v"])
    h, (k, v) = scan_layers(body, h, xs)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k, v
    new_cache["pos"] = cache["pos"] + 1
    h = norm(cfg, h, params["final_norm"])
    return h @ params["embed"].T, new_cache

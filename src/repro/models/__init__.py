"""Functional-JAX model zoo for the 10 assigned architectures."""

from .blocks import encode, forward, init_model, train_loss
from .decode import decode_step, init_cache

__all__ = ["decode_step", "encode", "forward", "init_cache", "init_model",
           "train_loss"]

"""Multi-head Latent Attention (DeepSeek-V2): train/prefill + two decode
paths.

MLA compresses KV into a per-token latent ``c_kv`` (kv_lora_rank wide) plus
one shared RoPE key head.  The cache stores only ``(c_kv, k_rope)`` — the
memory win that makes the 32k-decode shape feasible at 128 heads.

Decode ships in two mathematically-identical forms:

* ``expand`` (paper-faithful baseline): up-project the cached latents to
  full per-head K/V every step — memory-bandwidth heavy;
* ``absorbed`` (the optimized §Perf variant): fold W_uk into the query and
  W_uv into the output so attention runs directly in the 512-dim latent
  space — per-step FLOPs drop from O(S·H·(nope+v)) to O(S·(lora+rope))
  per head pair.  This is the beyond-paper hillclimb lever for the
  decode_32k cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, dtype_of, rms_norm, split_keys


def init_mla(cfg, key) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                            cfg.v_head_dim, cfg.kv_lora_rank)
    ks = split_keys(key, 6)
    dt = dtype_of(cfg)
    return {
        "wq": dense_init(ks[0], (d, h * (nope + rope)), dt),
        "w_dkv": dense_init(ks[1], (d, lora), dt),
        "w_kr": dense_init(ks[2], (d, rope), dt),
        "kv_norm": jnp.ones((lora,), dt),
        "w_uk": dense_init(ks[3], (lora, h * nope), dt),
        "w_uv": dense_init(ks[4], (lora, h * vd), dt),
        "wo": dense_init(ks[5], (h * vd, d), dt),
    }


def _project_q(cfg, p, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = (x @ p["wq"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(cfg, p, x, positions):
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)   # [B,S,lora]
    k_rope = (x @ p["w_kr"])[:, :, None, :]                        # [B,S,1,rope]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(cfg, p, x, positions):
    """Train/prefill: expand latents to per-head K/V, full causal attention.
    Returns (out, (c_kv, k_rope)) for the cache."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    c_kv, k_rope = _latents(cfg, p, x, positions)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, nope)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, vd)
    scale = (nope + rope) ** -0.5
    logits = (jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    logits = jnp.where((kj <= qi)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    out = o.reshape(b, s, h * vd).astype(x.dtype) @ p["wo"]
    return out, (c_kv, k_rope)


def mla_decode(cfg, p, x, pos, ckv_cache, kr_cache, *, absorbed: bool):
    """Single-step decode.  ckv_cache: [B, Smax, lora]; kr_cache:
    [B, Smax, rope].  Returns (out, ckv_cache, kr_cache)."""
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                            cfg.v_head_dim, cfg.kv_lora_rank)
    positions = pos[:, None]
    q_nope, q_rope = _project_q(cfg, p, x, positions)   # [B,1,H,*]
    c_kv, k_rope = _latents(cfg, p, x, positions)       # [B,1,lora],[B,1,rope]
    ckv_cache = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0))
    )(ckv_cache, c_kv, pos)
    kr_cache = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0))
    )(kr_cache, k_rope, pos)
    t = ckv_cache.shape[1]
    scale = (nope + rope) ** -0.5
    mask = jnp.arange(t)[None, :] <= pos[:, None]       # [B, T]

    if absorbed:
        # q_lat[h] = q_nope[h] @ W_uk[h]^T : attention scored in latent space
        w_uk = p["w_uk"].reshape(lora, h, nope)
        q_lat = jnp.einsum("bshd,lhd->bshl", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))    # [B,1,H,lora]
        logits = (jnp.einsum("bshl,btl->bhst", q_lat,
                             ckv_cache.astype(jnp.float32))
                  + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                               kr_cache.astype(jnp.float32))) * scale
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", probs,
                           ckv_cache.astype(jnp.float32))  # [B,1,H,lora]
        w_uv = p["w_uv"].reshape(lora, h, vd)
        o = jnp.einsum("bshl,lhd->bshd", o_lat, w_uv.astype(jnp.float32))
    else:
        k_nope = (ckv_cache @ p["w_uk"]).reshape(b, t, h, nope)
        v = (ckv_cache @ p["w_uv"]).reshape(b, t, h, vd)
        logits = (jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32),
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                               kr_cache.astype(jnp.float32))) * scale
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    out = o.reshape(b, 1, h * vd).astype(x.dtype) @ p["wo"]
    return out, ckv_cache, kr_cache

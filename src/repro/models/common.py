"""Shared model components: norms, RoPE (incl. M-RoPE and per-layer theta),
sinusoidal positions, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def scan_layers(body, carry, xs, length: int | None = None):
    """lax.scan with env-controlled unrolling.

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, which would hide ~n_layers of FLOPs/bytes from the roofline.
    The dry-run sets REPRO_SCAN_UNROLL=full so layer stacks unroll and the
    compiled module's cost_analysis reflects every layer; normal execution
    keeps the rolled loop (compact HLO, fast compiles).
    """
    import os
    mode = os.environ.get("REPRO_SCAN_UNROLL", "1")
    n = length if length is not None else jax.tree_util.tree_leaves(xs)[0].shape[0]
    unroll = n if mode == "full" else max(1, min(int(mode), n))
    return jax.lax.scan(body, carry, xs, unroll=unroll)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm(cfg, x: jnp.ndarray, p: dict) -> jnp.ndarray:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps, plus_one=(cfg.name.startswith("gemma")))


def norm_params(cfg, d: int) -> dict:
    if cfg.norm_type == "layernorm":
        return {"w": jnp.ones((d,), dtype_of(cfg)),
                "b": jnp.zeros((d,), dtype_of(cfg))}
    init = jnp.zeros if cfg.name.startswith("gemma") else jnp.ones
    return {"w": init((d,), dtype_of(cfg))}


# ----------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: jnp.ndarray | float) -> jnp.ndarray:
    """[head_dim/2] inverse frequencies; theta may be a traced scalar
    (per-layer theta for gemma3's local/global split)."""
    half = head_dim // 2
    exponent = jnp.arange(half, dtype=jnp.float32) / half
    return 1.0 / (jnp.asarray(theta, jnp.float32) ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: jnp.ndarray | float,
               mrope_sections: tuple[int, ...] = ()) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] (or [B, S, 3] for M-RoPE)."""
    b, s, h, d = x.shape
    inv = rope_freqs(d, theta)                       # [d/2]
    if mrope_sections:
        assert positions.ndim == 3
        sec = np.cumsum((0,) + tuple(mrope_sections))
        assert sec[-1] == d // 2
        sel = np.zeros(d // 2, np.int32)
        for i in range(len(mrope_sections)):
            sel[sec[i]:sec[i + 1]] = i
        pos = positions.astype(jnp.float32)[..., jnp.asarray(sel)]  # [B,S,d/2]
        ang = pos * inv[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        ang = positions.astype(jnp.float32)[:, :, None] * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]                # [B, S, 1, d/2]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(s: int, d: int) -> jnp.ndarray:
    """Whisper-style absolute sinusoidal embedding [S, D]."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale: float | None = None) -> jnp.ndarray:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))

"""Mamba2 block (SSD): init, full-sequence forward, single-step decode.

Block anatomy (Mamba2): in_proj -> [z | x | B | C | dt]; depthwise causal
conv over (x, B, C); SSD scan s_t = exp(dt A) s_{t-1} + dt B x^T, y = C s;
D-skip, SiLU(z) gating, RMSNorm, out_proj.

Full-sequence forward calls the pure-jnp SSD reference (the Pallas
``ssd_scan`` kernel is the TPU execution path, selectable with
``use_pallas``); decode keeps a (conv window, state) cache — O(1) per step,
which is what qualifies the SSM archs for the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, rms_norm, split_keys


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssd(cfg, key) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    g, n_ = cfg.ssm_groups, cfg.ssm_state
    nh = cfg.ssm_heads
    dt = dtype_of(cfg)
    ks = split_keys(key, 4)
    in_dim = 2 * di + 2 * g * n_ + nh      # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d, in_dim), dt),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_dim(cfg)), dt,
                             scale=cfg.conv_kernel ** -0.5),
        "conv_b": jnp.zeros((conv_dim(cfg),), dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "ssm_norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), dt),
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.d_inner
    g, n_ = cfg.ssm_groups, cfg.ssm_state
    nh = cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_dim(cfg)]
    dt = zxbcdt[..., di + conv_dim(cfg):di + conv_dim(cfg) + nh]
    del g, n_
    return z, xbc, dt


def _causal_conv(cfg, p, xbc):
    """Depthwise causal conv1d over [B, L, C]."""
    k = cfg.conv_kernel
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * p["conv_w"][i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + p["conv_b"])


def ssd_forward(cfg, p, h, *, use_pallas: bool = False):
    """Full-sequence forward.  h: [B, L, D] -> ([B, L, D], (conv_tail, state))."""
    b, L, _ = h.shape
    g, n_ = cfg.ssm_groups, cfg.ssm_state
    nh, pd = cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = h @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(cfg, p, xbc_raw)
    x = xbc[..., :cfg.d_inner].reshape(b, L, nh, pd)
    bm = xbc[..., cfg.d_inner:cfg.d_inner + g * n_].reshape(b, L, g, n_)
    cm = xbc[..., cfg.d_inner + g * n_:].reshape(b, L, g, n_)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    if use_pallas:
        from repro.kernels.ssd_scan import ssd_scan
        y = ssd_scan(x, dt.astype(x.dtype), a, bm, cm)
    else:
        from repro.kernels.ssd_scan.ref import ssd_scan_ref
        rep = nh // g
        bf = jnp.repeat(bm, rep, axis=2)
        cf = jnp.repeat(cm, rep, axis=2)
        xh = x.transpose(0, 2, 1, 3).reshape(b * nh, L, pd)
        dth = dt.transpose(0, 2, 1).reshape(b * nh, L)
        y = ssd_scan_ref(xh, dth, jnp.tile(a, b),
                         bf.transpose(0, 2, 1, 3).reshape(b * nh, L, n_),
                         cf.transpose(0, 2, 1, 3).reshape(b * nh, L, n_))
        y = y.reshape(b, nh, L, pd).transpose(0, 2, 1, 3)
    y = y + x * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, L, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    # cache tail: last (k-1) pre-conv features + final state (recomputed
    # cheaply by the decode path; prefill fills it via ssd_state below)
    conv_tail = xbc_raw[:, -(cfg.conv_kernel - 1):, :]
    return out, conv_tail


def ssd_final_state(cfg, p, h):
    """Final SSM state after a full sequence (for prefill->decode handoff).
    Returns [B, H, N, P] fp32."""
    b, L, _ = h.shape
    g, n_ = cfg.ssm_groups, cfg.ssm_state
    nh, pd = cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = h @ p["in_proj"]
    _z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(cfg, p, xbc_raw)
    x = xbc[..., :cfg.d_inner].reshape(b, L, nh, pd)
    bm = xbc[..., cfg.d_inner:cfg.d_inner + g * n_].reshape(b, L, g, n_)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    rep = nh // g
    bf = jnp.repeat(bm, rep, axis=2)                   # [B, L, H, N]

    def step(s, inp):
        xt, dtt, bt = inp                              # [B,H,P],[B,H],[B,H,N]
        lam = jnp.exp(dtt * a[None, :])[..., None, None]
        s = lam * s + dtt[..., None, None] * (
            bt[..., :, None] * xt[..., None, :].astype(jnp.float32))
        return s, None

    s0 = jnp.zeros((b, nh, n_, pd), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          bf.transpose(1, 0, 2, 3))
    s, _ = jax.lax.scan(step, s0, xs)
    return s


def ssd_decode(cfg, p, h, conv_cache, state):
    """Single step.  h: [B, 1, D]; conv_cache: [B, k-1, conv_dim] (pre-conv
    features); state: [B, H, N, P] fp32.  Returns (out, conv_cache, state)."""
    b = h.shape[0]
    g, n_ = cfg.ssm_groups, cfg.ssm_state
    nh, pd = cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = h @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)       # [B,1,*]
    window = jnp.concatenate([conv_cache, xbc_raw], axis=1)  # [B, k, C]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)                          # [B, C]
    x = xbc[..., :cfg.d_inner].reshape(b, nh, pd)
    bm = xbc[..., cfg.d_inner:cfg.d_inner + g * n_].reshape(b, g, n_)
    cm = xbc[..., cfg.d_inner + g * n_:].reshape(b, g, n_)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    rep = nh // g
    bf = jnp.repeat(bm, rep, axis=1)                     # [B, H, N]
    cf = jnp.repeat(cm, rep, axis=1)
    lam = jnp.exp(dt * a[None, :])[..., None, None]      # [B, H, 1, 1]
    state = lam * state + dt[..., None, None] * (
        bf[..., :, None] * x[..., None, :].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", cf.astype(jnp.float32), state)
    y = y.astype(h.dtype) + x * p["d_skip"][None, :, None].astype(h.dtype)
    y = y.reshape(b, 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    conv_cache = window[:, 1:, :]
    return out, conv_cache, state

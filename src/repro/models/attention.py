"""GQA attention (qwen/llama/gemma/yi families): init + train/prefill/decode.

The jnp path is the default (XLA fuses it, and the dry-run's
``cost_analysis`` then reflects true FLOPs); the Pallas flash kernel is the
TPU execution path, selectable with ``use_pallas=True`` (validated against
the same reference in tests).  Sliding windows arrive as *traced* per-layer
scalars so gemma3's 5:1 local:global pattern stays scannable — a window of
-1 means global.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, dtype_of, norm, norm_params, rms_norm, split_keys


def init_attn(cfg, key) -> dict:
    d = cfg.d_model
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 6)
    dt = dtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), dt),
        "wk": dense_init(ks[1], (d, hk * dh), dt),
        "wv": dense_init(ks[2], (d, hk * dh), dt),
        "wo": dense_init(ks[3], (h * dh, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _project_qkv(cfg, p, x, positions, theta):
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, hk, dh)
    v = (x @ p["wv"]).reshape(b, s, hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, theta, cfg.mrope_sections)
        k = apply_rope(k, positions, theta, cfg.mrope_sections)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, window, q_offset=0):
    """q: [B,S,H,Dh]; k,v: [B,T,Hk,Dh]; window: traced scalar, -1=global."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, dh)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (dh ** -0.5)
    qi = (jnp.arange(s) + q_offset)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kj <= qi
    w = jnp.asarray(window)
    mask &= jnp.where(w < 0, True, (qi - kj) < w)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(q.dtype)


def attn_forward(cfg, p, x, positions, theta, window,
                 *, use_pallas: bool = False):
    """Full-sequence causal attention (train / prefill).  Returns
    (out [B,S,D], (k, v) for cache)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions, theta)
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention
        win = int(window) if int(window) > 0 else None
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True, window=win)
        o = o.transpose(0, 2, 1, 3)
    else:
        o = _sdpa(q, k, v, causal=True, window=window)
    out = o.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, (k, v)


def attn_decode(cfg, p, x, pos, theta, window, k_cache, v_cache):
    """Single-step decode.  x: [B,1,D]; pos: [B] current index;
    k_cache/v_cache: [B, Smax, Hk, Dh].  Returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    positions = pos[:, None]                                   # [B,1]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    q, k, v = _project_qkv(cfg, p, x, positions, theta)
    k_cache = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
    )(k_cache, k, pos)
    v_cache = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
    )(v_cache, v, pos)
    t = k_cache.shape[1]
    hk, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, hk, g, cfg.head_dim)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    kj = jnp.arange(t)[None, :]
    mask = kj <= pos[:, None]                                  # [B, T]
    w = jnp.asarray(window)
    mask &= jnp.where(w < 0, True, (pos[:, None] - kj) < w)
    logits = jnp.where(mask[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", probs,
                   v_cache.astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, k_cache, v_cache


def init_block_norms(cfg, key) -> dict:
    del key
    p = {"attn_norm": norm_params(cfg, cfg.d_model),
         "mlp_norm": norm_params(cfg, cfg.d_model)}
    if cfg.post_norm:
        p["post_attn_norm"] = norm_params(cfg, cfg.d_model)
        p["post_mlp_norm"] = norm_params(cfg, cfg.d_model)
    return p


def block_norm(cfg, p, name, x):
    return norm(cfg, x, p[name])

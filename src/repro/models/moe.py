"""MLPs: SwiGLU / GELU dense blocks and the DeepSeek-V2-style MoE
(shared experts + top-k routed experts, capacity-bucket dispatch).

Dispatch is the TPU-standard dense formulation: tokens are scattered into
per-expert capacity buffers with one-hot position matrices, experts run as
batched matmuls ([E, C, d] × [E, d, f] — MXU-shaped, EP-shardable on the
expert axis), and outputs are combined with the router weights.  Dropped
tokens (capacity overflow) lose their routed contribution but keep the
shared-expert path, as in the reference systems.  The auxiliary
load-balance loss is returned for the training objective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, split_keys


# ---------------------------------------------------------------- dense MLP
def init_mlp(cfg, key, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = dtype_of(cfg)
    ks = split_keys(key, 3)
    if cfg.mlp_kind == "gelu":
        return {"w_up": dense_init(ks[0], (d, f), dt),
                "b_up": jnp.zeros((f,), dt),
                "w_down": dense_init(ks[1], (f, d), dt),
                "b_down": jnp.zeros((d,), dt)}
    return {"w_gate": dense_init(ks[0], (d, f), dt),
            "w_up": dense_init(ks[1], (d, f), dt),
            "w_down": dense_init(ks[2], (f, d), dt)}


def mlp_forward(cfg, p, x) -> jnp.ndarray:
    if "w_gate" in p:
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


# --------------------------------------------------------------------- MoE
def init_moe(cfg, key) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dt, scale=0.02),
        "e_gate": dense_init(ks[1], (e, d, f), dt),
        "e_up": dense_init(ks[2], (e, d, f), dt),
        "e_down": dense_init(ks[3], (e, f, d), dt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        ks2 = split_keys(ks[4], 3)
        p["s_gate"] = dense_init(ks2[0], (d, fs), dt)
        p["s_up"] = dense_init(ks2[1], (d, fs), dt)
        p["s_down"] = dense_init(ks2[2], (fs, d), dt)
    return p


def moe_forward(cfg, p, x) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out, aux_loss).

    Sort-based dispatch: assignments are ranked within their expert via an
    argsort (O(Nk log Nk), FLOP-free) and moved with scatter-add / gather —
    the initial one-hot-einsum formulation cost O(N·E·C·D) MXU FLOPs and
    dominated the whole roofline (recorded as the ``onehot_dispatch``
    variant in EXPERIMENTS.md §Perf; the switch cut DS-236B train-step HLO
    FLOPs ~4x).

    REPRO_MOE_GROUPS=G (§Perf, expert parallelism): dispatch is done in G
    batch-aligned groups (G = data-axis size), each with its own capacity
    slice, so a token's scatter never crosses the data axis — GSPMD lowers
    the exchange as expert-parallel all-to-all-style traffic instead of
    all-reducing the full global buffer (the ``moe_groups`` variant).
    """
    import os
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    groups = int(os.environ.get("REPRO_MOE_GROUPS", "1"))
    if groups > 1 and b % groups == 0:
        xg = x.reshape(groups, b // groups, s, d)
        out, aux = jax.vmap(
            lambda xi: _moe_tokens(cfg, p, xi.reshape(-1, d)))(
                xg.reshape(groups, -1, d))
        return out.reshape(b, s, d), jnp.mean(aux)
    out, aux = _moe_tokens(cfg, p, x.reshape(b * s, d))
    return out.reshape(b, s, d), aux


def _moe_tokens(cfg, p, xt) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch+compute+combine for a flat token block xt: [N, D]."""
    e, k = cfg.n_experts, cfg.top_k
    n, d = xt.shape

    logits = (xt @ p["router"]).astype(jnp.float32)           # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize

    # Capacity floor of 8 keeps tiny decode batches drop-free (a 1-token
    # step would otherwise drop assignments that prefill kept, breaking
    # prefill->decode parity); large batches get the usual cf*N*k/E.
    capacity = max(8, int(cfg.capacity_factor * n * k / e))

    # --- rank each assignment within its expert (sort-based, no one-hot) --
    flat_e = gate_idx.reshape(n * k)                          # [NK]
    order = jnp.argsort(flat_e, stable=True)                  # assignments
    sorted_e = flat_e[order]                                  # grouped by e
    start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank_sorted = jnp.arange(n * k) - start[sorted_e]
    rank = jnp.zeros((n * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))                        # [NK]
    keep = rank < capacity
    slot = jnp.where(keep, flat_e * capacity + rank, e * capacity)  # drop row

    # --- scatter tokens into [E*C(+1 drop row), D] buffers ----------------
    tok_idx = jnp.repeat(jnp.arange(n), k)
    buffers = jnp.zeros((e * capacity + 1, d), xt.dtype)
    buffers = buffers.at[slot].add(xt[tok_idx])
    buffers = buffers[:e * capacity].reshape(e, capacity, d)

    # --- batched expert MLPs  [E, C, d] x [E, d, f] ------------------------
    hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buffers, p["e_gate"]))
    hu = jnp.einsum("ecd,edf->ecf", buffers, p["e_up"])
    he = jnp.einsum("ecf,efd->ecd", hg * hu, p["e_down"])

    # --- gather back + combine with gate weights ---------------------------
    he_flat = jnp.concatenate(
        [he.reshape(e * capacity, d),
         jnp.zeros((1, d), he.dtype)], axis=0)
    per_slot = he_flat[slot].reshape(n, k, d)                 # [N, k, D]
    out = jnp.sum(per_slot.astype(jnp.float32)
                  * gate_vals[..., None], axis=1).astype(xt.dtype)

    if cfg.n_shared_experts:
        out = out + (jax.nn.silu(xt @ p["s_gate"]) * (xt @ p["s_up"])) @ p["s_down"]

    # load-balance auxiliary loss (switch-style)
    frac_tokens = (jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
                   / (n * k)) * k
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) / k
    return out, aux.astype(jnp.float32)

"""Model assembly: init / forward / decode for all four families.

Layer stacks are **scanned** (params stacked on a leading axis) so the
512-device dry-run compiles a single layer body instead of 60 copies;
heterogeneous per-layer attributes (gemma3's 5:1 local:global windows and
dual RoPE theta) ride through the scan as traced per-layer scalars.
Training wraps the scan body in ``jax.checkpoint`` (remat policy is a
§Perf knob).

Families:
  decoder — GQA or MLA attention × SwiGLU or MoE MLP (llama/gemma/yi/qwen/
            qwen-vl/deepseek); DS-V2's first dense layer is unrolled.
  ssm     — pure Mamba2 (SSD) stack.
  hybrid  — Mamba2 backbone with ONE shared attention block applied every
            ``attn_every`` layers (zamba2), each application with its own
            KV cache.
  encdec  — whisper backbone: bidirectional encoder over stub frame
            embeddings + causal decoder with cross-attention.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssd as ssd_mod
from .common import (dense_init, dtype_of, norm, norm_params, scan_layers,
                     sinusoidal_positions, split_keys)

Params = dict
Cache = dict


# ===================================================================== init
def init_model(cfg, key) -> Params:
    if cfg.family == "encdec":
        return _init_encdec(cfg, key)
    ks = split_keys(key, 8)
    dt = dtype_of(cfg)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "final_norm": norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dt)

    if cfg.family in ("ssm", "hybrid"):
        n_scan = cfg.n_layers
        p["layers"] = _stack_init(
            lambda k: _init_ssm_block(cfg, k), ks[2], n_scan)
        if cfg.attn_every:
            p["shared_attn"] = _init_attn_block(cfg, ks[3])
        return p

    n_scan = cfg.n_layers - cfg.first_dense_layers
    p["layers"] = _stack_init(lambda k: _init_decoder_block(cfg, k, dense=False),
                              ks[2], n_scan)
    for i in range(cfg.first_dense_layers):
        p[f"dense{i}"] = _init_decoder_block(cfg, ks[4 + i], dense=True)
    return p


def _stack_init(fn, key, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def _init_decoder_block(cfg, key, dense: bool) -> Params:
    ks = split_keys(key, 3)
    p = attn.init_block_norms(cfg, ks[0])
    if cfg.attn_kind == "mla":
        p["attn"] = mla_mod.init_mla(cfg, ks[1])
    else:
        p["attn"] = attn.init_attn(cfg, ks[1])
    if cfg.mlp_kind == "moe" and not dense:
        p["mlp"] = moe_mod.init_moe(cfg, ks[2])
    else:
        d_ff = cfg.dense_d_ff if (dense and cfg.dense_d_ff) else cfg.d_ff
        p["mlp"] = moe_mod.init_mlp(cfg, ks[2], d_ff=d_ff)
    return p


def _init_ssm_block(cfg, key) -> Params:
    ks = split_keys(key, 2)
    return {"norm": norm_params(cfg, cfg.d_model),
            "ssd": ssd_mod.init_ssd(cfg, ks[0])}


def _init_attn_block(cfg, key) -> Params:
    """zamba2's shared transformer block (attention + MLP)."""
    ks = split_keys(key, 3)
    return {"attn_norm": norm_params(cfg, cfg.d_model),
            "mlp_norm": norm_params(cfg, cfg.d_model),
            "attn": attn.init_attn(cfg, ks[0]),
            "mlp": moe_mod.init_mlp(cfg, ks[1], d_ff=cfg.d_ff)}


def _init_encdec(cfg, key) -> Params:
    ks = split_keys(key, 6)
    dt = dtype_of(cfg)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "final_norm": norm_params(cfg, cfg.d_model),
        "enc_final_norm": norm_params(cfg, cfg.d_model),
    }
    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"attn_norm": norm_params(cfg, cfg.d_model),
                "mlp_norm": norm_params(cfg, cfg.d_model),
                "attn": attn.init_attn(cfg, k1),
                "mlp": moe_mod.init_mlp(cfg, k2)}
    def dec_block(k):
        k1, k2, k3 = split_keys(k, 3)
        return {"attn_norm": norm_params(cfg, cfg.d_model),
                "cross_norm": norm_params(cfg, cfg.d_model),
                "mlp_norm": norm_params(cfg, cfg.d_model),
                "attn": attn.init_attn(cfg, k1),
                "cross": attn.init_attn(cfg, k2),
                "mlp": moe_mod.init_mlp(cfg, k3)}
    p["encoder"] = _stack_init(enc_block, ks[1], cfg.enc_layers)
    p["layers"] = _stack_init(dec_block, ks[2], cfg.n_layers)
    return p


# ================================================================ per-layer
def _layer_meta(cfg):
    """Traced per-layer (theta, window) arrays for the scan."""
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    thetas = np.full(cfg.n_layers, cfg.rope_theta, np.float32)
    if cfg.rope_theta_global is not None:
        for i, w in enumerate(cfg.layer_windows()):
            if w < 0:
                thetas[i] = cfg.rope_theta_global
    n_scan = cfg.n_layers - cfg.first_dense_layers
    return (jnp.asarray(thetas[cfg.first_dense_layers:]),
            windows[cfg.first_dense_layers:],
            jnp.asarray(thetas[:cfg.first_dense_layers]),
            windows[:cfg.first_dense_layers],
            n_scan)


def _decoder_block_fwd(cfg, p, h, positions, theta, window, *, dense: bool,
                       use_pallas: bool):
    aux = jnp.zeros((), jnp.float32)
    a_in = norm(cfg, h, p["attn_norm"])
    if cfg.attn_kind == "mla":
        a_out, kv = mla_mod.mla_forward(cfg, p["attn"], a_in, positions)
    else:
        a_out, kv = attn.attn_forward(cfg, p["attn"], a_in, positions, theta,
                                      window, use_pallas=use_pallas)
    if cfg.post_norm:
        a_out = norm(cfg, a_out, p["post_attn_norm"])
    h = h + a_out
    m_in = norm(cfg, h, p["mlp_norm"])
    if cfg.mlp_kind == "moe" and not dense:
        m_out, aux = moe_mod.moe_forward(cfg, p["mlp"], m_in)
    else:
        m_out = moe_mod.mlp_forward(cfg, p["mlp"], m_in)
    if cfg.post_norm:
        m_out = norm(cfg, m_out, p["post_mlp_norm"])
    return h + m_out, kv, aux


def _remat_policy():
    """Activation-checkpoint policy, env-selectable for §Perf sweeps:
    REPRO_REMAT = nothing (default, min memory) | dots (save matmul
    outputs, ~25% less recompute) | none."""
    import os
    mode = os.environ.get("REPRO_REMAT", "nothing")
    if mode == "dots":
        return jax.checkpoint_policies.dots_saveable
    if mode == "none":
        return jax.checkpoint_policies.everything_saveable
    return jax.checkpoint_policies.nothing_saveable


# ================================================================== forward
def forward(cfg, params: Params, batch: dict, *, mode: str = "train",
            use_pallas: bool = False, remat: bool = True,
            cache_len: int | None = None) -> Any:
    """mode='train': returns (logits, aux).  mode='prefill': returns
    (last_logits, cache) with KV caches sized ``cache_len or S``."""
    assert mode in ("train", "prefill")
    if cfg.family == "encdec":
        return _encdec_forward(cfg, params, batch, mode=mode, remat=remat,
                               cache_len=cache_len)
    if cfg.family in ("ssm", "hybrid"):
        return _ssm_forward(cfg, params, batch, mode=mode, remat=remat,
                            cache_len=cache_len, use_pallas=use_pallas)

    tokens = batch.get("tokens")
    if tokens is not None:
        h = jnp.take(params["embed"], tokens, axis=0)
    else:
        h = batch["embeds"]
    b, s = h.shape[0], h.shape[1]
    if cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

    thetas, windows, d_thetas, d_windows, n_scan = _layer_meta(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    dense_caches = []
    for i in range(cfg.first_dense_layers):
        h, kv, aux = _decoder_block_fwd(
            cfg, params[f"dense{i}"], h, positions, d_thetas[i], d_windows[i],
            dense=True, use_pallas=use_pallas)
        aux_total += aux
        dense_caches.append(kv)

    want_cache = mode == "prefill"

    def body(carry, xs):
        h, aux_acc = carry
        lp, theta, window = xs
        h, kv, aux = _decoder_block_fwd(cfg, lp, h, positions, theta, window,
                                        dense=False, use_pallas=use_pallas)
        return (h, aux_acc + aux), (kv if want_cache else 0)

    if remat and mode == "train":
        body = jax.checkpoint(body, policy=_remat_policy())
    (h, aux_total), kvs = scan_layers(
        body, (h, aux_total), (params["layers"], thetas, windows))

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    if mode == "train":
        logits = h @ head
        return logits, aux_total

    logits = h[:, -1:] @ head           # prefill: only the last position
    cache = _pack_prefill_cache(cfg, kvs, dense_caches, s, cache_len)
    return logits, cache


def _pack_prefill_cache(cfg, kvs, dense_caches, s: int,
                        cache_len: int | None) -> Cache:
    cache: Cache = {}
    target = cache_len or s

    def grow(x, axis):
        if target == s:
            return x
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, target - s)
        return jnp.pad(x, pad)

    if cfg.attn_kind == "mla":
        ckv, kr = kvs
        cache["ckv"] = grow(ckv, 2)      # [L, B, S, lora]
        cache["kr"] = grow(kr, 2)
        if dense_caches:
            cache["d_ckv"] = grow(jnp.stack([c[0] for c in dense_caches]), 2)
            cache["d_kr"] = grow(jnp.stack([c[1] for c in dense_caches]), 2)
    else:
        k, v = kvs
        cache["k"] = grow(k, 2)          # [L, B, S, Hk, Dh]
        cache["v"] = grow(v, 2)
    cache["pos"] = jnp.full((1,), s, jnp.int32)
    return cache


# ============================================================ ssm / hybrid
def _ssm_block_fwd(cfg, p, h, *, want_state: bool, use_pallas: bool):
    a_in = norm(cfg, h, p["norm"])
    out, conv_tail = ssd_mod.ssd_forward(cfg, p["ssd"], a_in,
                                         use_pallas=use_pallas)
    state = (ssd_mod.ssd_final_state(cfg, p["ssd"], a_in)
             if want_state else jnp.zeros((), jnp.float32))
    return h + out, conv_tail, state


def _shared_attn_fwd(cfg, p, h, positions, *, use_pallas: bool):
    a_in = norm(cfg, h, p["attn_norm"])
    a_out, kv = attn.attn_forward(cfg, p["attn"], a_in, positions,
                                  cfg.rope_theta, jnp.int32(-1),
                                  use_pallas=use_pallas)
    h = h + a_out
    m_in = norm(cfg, h, p["mlp_norm"])
    return h + moe_mod.mlp_forward(cfg, p["mlp"], m_in), kv


def _ssm_forward(cfg, params, batch, *, mode, remat, cache_len,
                 use_pallas: bool):
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    want_cache = mode == "prefill"

    def body(carry, lp):
        h, aux = carry
        h, conv_tail, state = _ssm_block_fwd(cfg, lp, h,
                                             want_state=want_cache,
                                             use_pallas=use_pallas)
        out = (conv_tail, state) if want_cache else 0
        return (h, aux), out

    if remat and mode == "train":
        body = jax.checkpoint(body, policy=_remat_policy())

    aux0 = jnp.zeros((), jnp.float32)
    attn_kvs = []
    if cfg.attn_every:
        layers = params["layers"]
        n = cfg.n_layers
        outs = []
        pos_cursor = 0
        for seg_start in range(0, n, cfg.attn_every):
            seg_end = min(seg_start + cfg.attn_every, n)
            seg = jax.tree.map(lambda x: x[seg_start:seg_end], layers)
            (h, aux0), out = scan_layers(body, (h, aux0), seg)
            if want_cache:
                outs.append(out)
            if seg_end < n:
                h, kv = _shared_attn_fwd(cfg, params["shared_attn"], h,
                                         positions, use_pallas=use_pallas)
                attn_kvs.append(kv)
        del pos_cursor
        if want_cache:
            conv = jnp.concatenate([o[0] for o in outs], axis=0)
            state = jnp.concatenate([o[1] for o in outs], axis=0)
            kvs = (conv, state)
    else:
        (h, aux0), kvs = scan_layers(body, (h, aux0), params["layers"])

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if mode == "train":
        return h @ head, aux0

    logits = h[:, -1:] @ head
    conv, state = kvs
    cache: Cache = {"conv": conv, "state": state,
                    "pos": jnp.full((1,), s, jnp.int32)}
    if cfg.attn_every and attn_kvs:
        target = cache_len or s
        k = jnp.stack([kv[0] for kv in attn_kvs])
        v = jnp.stack([kv[1] for kv in attn_kvs])
        if target != s:
            pad = [(0, 0)] * k.ndim
            pad[2] = (0, target - s)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache["attn_k"], cache["attn_v"] = k, v
    return logits, cache


# ================================================================== encdec
def _enc_block_fwd(cfg, p, h):
    a_in = norm(cfg, h, p["attn_norm"])
    b, s, _ = a_in.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    # bidirectional self-attention (no causal mask)
    q, k, v = attn._project_qkv(cfg, p["attn"], a_in, positions, cfg.rope_theta)
    o = attn._sdpa(q, k, v, causal=False, window=jnp.int32(-1))
    a_out = o.reshape(b, s, -1) @ p["attn"]["wo"]
    h = h + a_out
    m_in = norm(cfg, h, p["mlp_norm"])
    return h + moe_mod.mlp_forward(cfg, p["mlp"], m_in)


def _cross_attn(cfg, p, x, enc_k, enc_v):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    o = attn._sdpa(q, enc_k, enc_v, causal=False, window=jnp.int32(-1))
    return o.reshape(b, s, h * dh) @ p["wo"]


def encode(cfg, params, enc_embeds):
    """Run the encoder over stub frame embeddings: [B, T, D] -> [B, T, D]."""
    h = enc_embeds + sinusoidal_positions(
        enc_embeds.shape[1], cfg.d_model).astype(enc_embeds.dtype)[None]

    def body(h, lp):
        return _enc_block_fwd(cfg, lp, h), 0

    h, _ = scan_layers(body, h, params["encoder"])
    return norm(cfg, h, params["enc_final_norm"])


def _encdec_forward(cfg, params, batch, *, mode, remat, cache_len):
    tokens = batch["tokens"]
    enc_out = encode(cfg, params, batch["encoder_embeds"])
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h + sinusoidal_positions(s, cfg.d_model).astype(h.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    want_cache = mode == "prefill"

    def body(carry, lp):
        h = carry
        a_in = norm(cfg, h, lp["attn_norm"])
        a_out, kv = attn.attn_forward(cfg, lp["attn"], a_in, positions,
                                      cfg.rope_theta, jnp.int32(-1))
        h = h + a_out
        c_in = norm(cfg, h, lp["cross_norm"])
        ck = (enc_out @ lp["cross"]["wk"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        cv = (enc_out @ lp["cross"]["wv"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        h = h + _cross_attn(cfg, lp["cross"], c_in, ck, cv)
        m_in = norm(cfg, h, lp["mlp_norm"])
        h = h + moe_mod.mlp_forward(cfg, lp["mlp"], m_in)
        return h, ((kv, (ck, cv)) if want_cache else 0)

    if remat and mode == "train":
        body = jax.checkpoint(body, policy=_remat_policy())
    h, kvs = scan_layers(body, h, params["layers"])
    h = norm(cfg, h, params["final_norm"])
    logits_head = params["embed"].T

    if mode == "train":
        return h @ logits_head, jnp.zeros((), jnp.float32)

    logits = h[:, -1:] @ logits_head
    (k, v), (ck, cv) = kvs
    target = cache_len or s
    if target != s:
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, target - s)
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = {"k": k, "v": v, "cross_k": ck, "cross_v": cv,
             "pos": jnp.full((1,), s, jnp.int32)}
    return logits, cache


# ==================================================================== loss
@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas", "remat"))
def train_loss(cfg, params, batch, *, use_pallas: bool = False,
               remat: bool = True):
    logits, aux = forward(cfg, params, batch, mode="train",
                          use_pallas=use_pallas, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = labels >= 0
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1)
    return nll + 0.01 * aux

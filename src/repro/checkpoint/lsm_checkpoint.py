"""Incremental LSM checkpointing — the paper's design applied to training
fault tolerance.

Every ``save`` splits each parameter leaf into fixed-size pages, hashes
them, and writes ONLY the changed pages to an append-only segment file
(the "flush").  Page→version mappings go through a real
:class:`repro.core.LSMTree` running the **vLSM policy**: small SSTs, no
tiering, Φ between L1/L2, overlap-aware vSSTs — so the index's compaction
chains (the thing that stalls RocksDB-style metadata stores for seconds
under churn) stay narrow, and the number of live segments a restore must
touch (read amplification = chain length) stays bounded.  Dead segments
are reference-counted and garbage-collected as compaction supersedes their
entries.

Restore reassembles full logical arrays (newest version per page) and
``device_put``s them under ANY mesh/sharding — elastic resizing is a
restore with a different mesh (examples/train_lm.py exercises
kill→restore→reshard).  ``async_save`` moves host serialization off the
step path.
"""

from __future__ import annotations

import hashlib
import json
import threading

from pathlib import Path

import numpy as np

import jax

from repro.core import LSMConfig, LSMTree, Policy

PAGE_BYTES = 1 << 18   # 256 KiB logical pages


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path)
             for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class LSMCheckpointStore:
    def __init__(self, root: str | Path, *, page_bytes: int = PAGE_BYTES,
                 lsm_cfg: LSMConfig | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        (self.root / "segments").mkdir(exist_ok=True)
        self.page_bytes = page_bytes
        # the version index: key = page_id, seq = monotonically increasing
        # write id; vLSM policy per the paper.
        self.index = LSMTree(lsm_cfg or LSMConfig.vlsm_default(scale=1 << 18)
                             .with_(kv_size=64))
        self.locator: dict[int, tuple[str, str, int]] = {}  # seq -> (seg, leaf, page)
        self.page_hash: dict[int, bytes] = {}
        self.seg_live: dict[str, int] = {}
        self.steps: dict[int, dict] = {}
        self._leaf_ids: dict[str, int] = {}
        # monotonic per-store segment sequence: segment names must be
        # unique and deterministic across reruns (wall-clock suffixes
        # collide under fast saves and break replay comparisons)
        self._seg_seq = 0
        self._lock = threading.Lock()
        self._pending: list[threading.Thread] = []
        self._load_manifest()

    # ------------------------------------------------------------ manifest
    def _manifest_path(self) -> Path:
        return self.root / "MANIFEST.json"

    def _save_manifest(self):
        m = {
            "locator": {str(k): v for k, v in self.locator.items()},
            "steps": {str(k): v for k, v in self.steps.items()},
            "leaf_ids": self._leaf_ids,
            "seg_live": self.seg_live,
        }
        tmp = self._manifest_path().with_suffix(".tmp")
        tmp.write_text(json.dumps(m))
        tmp.replace(self._manifest_path())

    def _load_manifest(self):
        p = self._manifest_path()
        if not p.exists():
            return
        m = json.loads(p.read_text())
        self.locator = {int(k): tuple(v) for k, v in m["locator"].items()}
        self.steps = {int(k): v for k, v in m["steps"].items()}
        self._leaf_ids = m["leaf_ids"]
        self.seg_live = m["seg_live"]
        # resume the segment sequence past every name ever recorded
        for names in (self.seg_live, {s for s, _l, _p in
                                      self.locator.values()}):
            for seg in names:
                try:
                    self._seg_seq = max(self._seg_seq,
                                        int(seg.rsplit("_", 1)[-1]) + 1)
                except ValueError:
                    pass
        # rebuild the LSM index from the manifest (WAL-equivalent)
        for seq in sorted(self.locator):
            seg, leaf, page = self.locator[seq]
            pid = self._page_id(leaf, page)
            self._index_put(pid)

    # ------------------------------------------------------------ plumbing
    def _page_id(self, leaf_name: str, page_no: int) -> int:
        lid = self._leaf_ids.setdefault(leaf_name, len(self._leaf_ids))
        return (lid << 32) | page_no

    def _index_put(self, page_id: int) -> int:
        tree = self.index
        if tree.memtable.room < 1:
            tree.seal_memtable()
            tree.flush_immutable()
            tree.background_triggers()
            tree.drain_jobs()
        seq = tree.put_batch(np.asarray([page_id], np.int64))[0]
        return int(seq)

    def _pages(self, arr: np.ndarray):
        raw = arr.tobytes()
        for i in range(0, max(len(raw), 1), self.page_bytes):
            yield i // self.page_bytes, raw[i:i + self.page_bytes]

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree) -> dict:
        """Synchronous incremental save.  Returns stats."""
        names, leaves, _ = _leaf_paths(tree)
        host = [np.asarray(x) for x in leaves]
        return self._save_host(step, names, host)

    def async_save(self, step: int, tree) -> threading.Thread:
        """Device->host copy happens now; serialization off-thread."""
        names, leaves, _ = _leaf_paths(tree)
        host = [np.asarray(x) for x in leaves]
        t = threading.Thread(target=self._save_host, args=(step, names, host))
        t.start()
        self._pending.append(t)
        return t

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _save_host(self, step: int, names, host_leaves) -> dict:
        with self._lock:
            seg_name = f"seg_{step:08d}_{self._seg_seq:06d}"
            self._seg_seq += 1
            seg_path = self.root / "segments" / f"{seg_name}.npz"
            payload: dict[str, np.ndarray] = {}
            written = total = 0
            meta = {}
            for name, arr in zip(names, host_leaves):
                meta[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
                for page_no, blob in self._pages(arr):
                    total += 1
                    pid = self._page_id(name, page_no)
                    digest = hashlib.blake2b(blob, digest_size=16).digest()
                    if self.page_hash.get(pid) == digest:
                        continue
                    self.page_hash[pid] = digest
                    seq = self._index_put(pid)
                    self.locator[seq] = (seg_name, name, page_no)
                    payload[f"{seq}"] = np.frombuffer(blob, np.uint8)
                    written += 1
            if payload:
                np.savez(seg_path, **payload)
                self.seg_live[seg_name] = len(payload)
            self.steps[step] = {"meta": meta,
                                "max_seq": int(self.index.seq) - 1}
            self._gc()
            self._save_manifest()
            return {"pages_written": written, "pages_total": total,
                    "segment": seg_name if payload else None}

    # -------------------------------------------------------------- restore
    def restore(self, step: int | None = None, *, treedef_like=None,
                shardings=None):
        """Rebuild params at ``step`` (default: latest).  ``treedef_like``
        is any pytree with the same structure (e.g. eval_shape output);
        ``shardings`` an optional matching sharding pytree for the target
        mesh (elastic reshard)."""
        with self._lock:
            assert self.steps, "empty store"
            step = max(self.steps) if step is None else step
            info = self.steps[step]
            max_seq = info["max_seq"]
            # newest version of each page at `step` (ascending overwrite)
            want: dict[int, int] = {}
            for seq in sorted(self.locator):
                if seq > max_seq:
                    break
                _seg, name, page = self.locator[seq]
                want[self._page_id(name, page)] = seq
            segments_touched = set()
            seg_cache: dict[str, dict] = {}
            out_leaves = []
            names = list(info["meta"])
            for name in names:
                m = info["meta"][name]
                dtype = np.dtype(m["dtype"])
                nbytes = int(np.prod(m["shape"]) * dtype.itemsize) \
                    if m["shape"] else dtype.itemsize
                buf = bytearray(nbytes)
                n_pages = max(1, -(-nbytes // self.page_bytes))
                for page_no in range(n_pages):
                    pid = self._page_id(name, page_no)
                    seq = want.get(pid)
                    assert seq is not None, f"missing page {name}:{page_no}"
                    seg, _n, _p = self.locator[seq]
                    segments_touched.add(seg)
                    if seg not in seg_cache:
                        seg_cache[seg] = dict(np.load(
                            self.root / "segments" / f"{seg}.npz"))
                    blob = seg_cache[seg][str(seq)].tobytes()
                    off = page_no * self.page_bytes
                    buf[off:off + len(blob)] = blob
                arr = np.frombuffer(bytes(buf), dtype=dtype)
                arr = arr.reshape(m["shape"]) if m["shape"] else arr[0]
                out_leaves.append(arr)
            stats = {"segments_touched": len(segments_touched),
                     "segments_total": len(self.seg_live)}
            if treedef_like is not None:
                _, _, treedef = _leaf_paths(treedef_like)
                tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
            else:
                tree = dict(zip(names, out_leaves))
            if shardings is not None:
                tree = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), tree, shardings)
            return tree, stats

    # ------------------------------------------------------------------ gc
    def _gc(self):
        """Drop segments whose every page version has been superseded."""
        live_view = self.index.merged_view()
        live_seqs = set(live_view.values())
        counts: dict[str, int] = {}
        for seq, (seg, _n, _p) in self.locator.items():
            if seq in live_seqs:
                counts[seg] = counts.get(seg, 0) + 1
        # keep segments needed by ANY recorded step (we only GC below the
        # oldest retained step's max_seq)
        min_keep = min((s["max_seq"] for s in self.steps.values()), default=0)
        dead = []
        for seg in list(self.seg_live):
            if counts.get(seg, 0) == 0:
                seqs = [q for q, (g, _n, _p) in self.locator.items()
                        if g == seg]
                if seqs and max(seqs) <= min_keep:
                    continue  # old step may still reference -> conservative
                if not seqs:
                    dead.append(seg)
        for seg in dead:
            (self.root / "segments" / f"{seg}.npz").unlink(missing_ok=True)
            self.seg_live.pop(seg, None)

    def retain(self, last_n: int = 2):
        """Forget all but the newest n steps (enables GC of old segments)."""
        with self._lock:
            keep = sorted(self.steps)[-last_n:]
            self.steps = {k: v for k, v in self.steps.items() if k in keep}

    def index_stats(self) -> dict:
        return self.index.stats.summary()

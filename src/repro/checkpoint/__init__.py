from .lsm_checkpoint import PAGE_BYTES, LSMCheckpointStore

__all__ = ["LSMCheckpointStore", "PAGE_BYTES"]

# launch layer: mesh factory, dry-run driver, train/serve entry points.

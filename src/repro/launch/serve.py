"""Serving driver: batched prefill + decode with the LSM-backed prefix
cache and paged KV pool.

The request loop is the paper's serving integration point: every admitted
prompt first consults the PrefixCache (vLSM-indexed), reuses pinned pages
for the matched prefix, prefills only the tail, then decodes with the
standard cache path (the paged-attention Pallas kernel is the TPU
execution path for the page pool; CPU smoke uses the dense cache).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --requests 12 --decode 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, forward, init_cache, init_model
from repro.serving import PagePool, PrefixCache, TokenBucket, poisson_arrivals


def make_requests(n: int, vocab: int, *, prefix_len: int = 128,
                  tail_max: int = 64, seed: int = 0):
    """Requests sharing one of two system prefixes (prefix-cache-friendly)."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, prefix_len),
                rng.integers(0, vocab, prefix_len)]
    reqs = []
    for i in range(n):
        pre = prefixes[i % 2]
        tail = rng.integers(0, vocab, int(rng.integers(8, tail_max)))
        reqs.append(np.concatenate([pre, tail]).astype(np.int32))
    return reqs


def run(arch: str, *, smoke: bool = True, n_requests: int = 8,
        decode_tokens: int = 16, block_tokens: int = 32,
        max_seq: int = 512, seed: int = 0, rate_ops_s: float = 50.0,
        limit_ops_s: float = 0.0, burst_ops: float = 4.0) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(seed)
    params = init_model(cfg, key)

    pool = PagePool(n_pages=256, page_size=block_tokens,
                    n_layers=max(cfg.n_layers, 1), n_kv_heads=max(cfg.n_kv_heads, 1),
                    head_dim=max(cfg.head_dim, 1))
    pcache = PrefixCache(pool, block_tokens=block_tokens)

    prefill = jax.jit(lambda p, b: forward(cfg, p, b, mode="prefill",
                                           cache_len=max_seq, remat=False))
    step = jax.jit(lambda p, t, pos, c: decode_step(cfg, p, t, pos, c))

    reqs = make_requests(n_requests, cfg.vocab_size, seed=seed)
    # open-loop arrival schedule (repro.serving.traffic's generator) paces
    # the admission clock: the token bucket refills along the seeded
    # Poisson timeline, not the prefill/decode wall clock, so the
    # admitted/rejected split is deterministic per (seed, rate, limit)
    arrivals = poisson_arrivals(n_requests, rate_ops_s,
                                np.random.default_rng(seed + 1))
    bucket = TokenBucket(rate_ops_s=limit_ops_s, burst_ops=burst_ops)
    stats = {"prefix_hits": 0, "tokens_prefilled": 0, "tokens_reused": 0,
             "requests_offered": n_requests, "requests_admitted": 0,
             "requests_rejected": 0, "latency_ms": []}
    outputs = []
    for r_id, tokens in enumerate(reqs):
        if not bucket.try_admit(float(arrivals[r_id])):
            stats["requests_rejected"] += 1
            continue
        stats["requests_admitted"] += 1
        t0 = time.monotonic()
        matched, _pages = pcache.match(tokens)
        stats["tokens_reused"] += matched
        if matched:
            stats["prefix_hits"] += 1
        # (CPU smoke prefills the full prompt into a dense cache; on TPU the
        # matched pages are reused directly through paged_attention.)
        batch = {"tokens": jnp.asarray(tokens[None])}
        if cfg.family == "encdec":
            rng = np.random.default_rng(r_id)
            batch["encoder_embeds"] = jnp.asarray(rng.standard_normal(
                (1, cfg.enc_seq, cfg.d_model)), jnp.dtype(cfg.param_dtype))
        logits, cache = prefill(params, batch)
        stats["tokens_prefilled"] += len(tokens) - matched
        # register this prompt's blocks in the prefix cache
        n_blocks = len(tokens) // block_tokens
        pages_by_block = []
        for _ in range(n_blocks):
            pages_by_block.append([pool.alloc()])
        pcache.insert(tokens, pages_by_block)

        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out = [int(tok[0, 0])]
        pos = jnp.asarray([len(tokens)], jnp.int32)
        for t in range(decode_tokens - 1):
            lg, cache = step(params, tok, pos + t, cache)
            tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
        outputs.append(out)
        stats["latency_ms"].append((time.monotonic() - t0) * 1e3)

    stats["prefix_cache"] = pcache.stats()
    lat = stats["latency_ms"]
    stats["p50_ms"] = float(np.percentile(lat, 50)) if lat else 0.0
    stats["p99_ms"] = float(np.percentile(lat, 99)) if lat else 0.0
    return {"outputs": outputs, "stats": stats}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="offered request rate (Poisson, ops/s)")
    ap.add_argument("--limit", type=float, default=0.0,
                    help="admission token-bucket rate (ops/s; 0 = off)")
    ap.add_argument("--burst", type=float, default=4.0,
                    help="admission token-bucket burst size (ops)")
    args = ap.parse_args()
    out = run(args.arch, n_requests=args.requests,
              decode_tokens=args.decode, rate_ops_s=args.rate,
              limit_ops_s=args.limit, burst_ops=args.burst)
    s = out["stats"]
    print(f"served {s['requests_admitted']}/{s['requests_offered']} requests"
          f" ({s['requests_rejected']} rejected);"
          f" prefix hits {s['prefix_hits']}"
          f" reused {s['tokens_reused']} tok; p50 {s['p50_ms']:.0f}ms"
          f" p99 {s['p99_ms']:.0f}ms")
    print("prefix cache:", s["prefix_cache"])


if __name__ == "__main__":
    main()

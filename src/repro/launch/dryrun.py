import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the production mesh (16×16 single-pod /
2×16×16 multi-pod over 512 placeholder host devices), constructs
ShapeDtypeStruct inputs with NamedShardings (launch/specs.py — zero
allocation), jits the cell's step function (train_step / prefill /
serve_step), ``.lower().compile()``s it, and records:

  * ``memory_analysis()``  — proves the per-device working set fits;
  * ``cost_analysis()``    — HLO FLOPs + bytes for §Roofline;
  * collective wire bytes  — parsed from the post-SPMD ``as_text()`` HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), per-device, with ring-algorithm wire factors.

Results are cached as JSON per cell under ``results/dryrun/`` so reruns
skip finished cells.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--force]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config
from repro.configs.registry import ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_specs, needs_fsdp, prefill_specs, train_specs
from repro.models import decode_step, forward
from repro.training.optimizer import AdamWConfig, adamw_update
from repro.training.step import make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective type, from post-SPMD HLO.

    Shapes in partitioned HLO are per-device.  Ring-algorithm factors:
    all-gather ~= result bytes (receives G-1 of G shards), all-reduce ~=
    2x bytes (reduce-scatter + all-gather phases), reduce-scatter ~=
    input ~= result*G, all-to-all / permute ~= bytes.
    """
    totals: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = None
        for op in ("all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute"):
            token = f" {op}(" if f" {op}(" in line else (
                f" {op}-start(" if f" {op}-start(" in line else None)
            if token:
                m = op
                break
        if m is None or "=" not in line:
            continue
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(")[0]
        shapes = _TUPLE_RE.findall(lhs.split("=", 1)[1])
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes
                     if dt in _DTYPE_BYTES)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(1, len([x for x in gm.group(1).split(",") if x.strip()]))
        if m == "all-reduce":
            wire = 2.0 * nbytes * max(g - 1, 1) / max(g, 1)
        elif m == "all-gather":
            wire = nbytes * max(g - 1, 1) / max(g, 1)
        elif m == "reduce-scatter":
            wire = nbytes * max(g - 1, 1)
        else:
            wire = float(nbytes)
        totals[m] = totals.get(m, 0.0) + wire
        count[m] = count.get(m, 0) + 1
    totals["_count"] = sum(count.values())
    totals["per_op_counts"] = count
    return totals


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               fsdp: bool | None = None, variant: str = "baseline",
               extra_cfg: dict | None = None):
    """Build + lower + compile one cell; returns (result dict, compiled)."""
    cfg = get_config(arch)
    if extra_cfg:
        cfg = cfg.with_(**extra_cfg)
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and shape.seq_len > 100_000 and not cfg.sub_quadratic:
        return {"skipped": "long_500k needs sub-quadratic attention "
                           "(full-attention arch; see DESIGN.md)"}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v

    t0 = time.perf_counter()
    if shape.kind == "train":
        params, opt, batch = train_specs(cfg, shape, mesh, fsdp=fsdp)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                from repro.models import train_loss
                return train_loss(cfg, p, batch, remat=True)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state, gnorm = adamw_update(AdamWConfig(), params,
                                                    grads, opt_state)
            return params, opt_state, loss, gnorm

        fn = jax.jit(train_step, donate_argnums=(0, 1))
        args = (params, opt, batch)
    elif shape.kind == "prefill":
        params, batch = prefill_specs(cfg, shape, mesh, fsdp=fsdp)

        def prefill_step(params, batch):
            return forward(cfg, params, batch, mode="prefill", remat=False)

        fn = jax.jit(prefill_step)
        args = (params, batch)
    else:
        params, tokens, pos, cache = decode_specs(cfg, shape, mesh, fsdp=fsdp)

        def serve_step(params, tokens, pos, cache):
            return decode_step(cfg, params, tokens, pos, cache,
                               absorbed_mla=(variant != "expand_mla"))

        fn = jax.jit(serve_step, donate_argnums=(3,))
        args = (params, tokens, pos, cache)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0)
            or getattr(mem, "temp_size_in_bytes", 0),
        }
    except Exception as e:  # CPU backend may not expose it
        mem_d = {"error": str(e)}

    coll = collective_bytes(compiled.as_text())
    wire = sum(v for k, v in coll.items()
               if k not in ("_count", "per_op_counts"))

    flops = float(cost.get("flops", 0.0))           # per-device
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    # roofline terms (seconds); cost_analysis is per-device post-SPMD
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = wire / LINK_BW

    model_flops = 6 * cfg.active_param_count() * shape.global_batch * (
        shape.seq_len if shape.kind == "train" else 1)
    if shape.kind == "prefill":
        model_flops = 2 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        model_flops = 2 * cfg.active_param_count() * shape.global_batch

    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    result = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "fsdp": bool(fsdp if fsdp is not None else needs_fsdp(cfg, mesh)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "collective_wire_bytes_per_device": wire,
        "collectives": coll,
        "memory": mem_d,
        "roofline": {
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / (flops * chips)
                               if flops else 0.0),
    }
    return result, compiled


def run_composed(arch: str, shape_name: str, *, multi_pod: bool,
                 variant: str = "baseline", fsdp=None) -> dict:
    """Composed costing for cells whose fully-unrolled compile is
    impractical on one CPU core (deepseek-236B train: 59 unrolled MoE
    layers + backward).  Exact decomposition:

      total = rolled + (L_scan - 1) x layer_body

    where ``layer_body`` = delta between two small UNROLLED compiles
    (L_scan = 2 vs 1 — identical top-level, one extra layer), and
    ``rolled`` is the full-depth rolled-scan compile (counts the body once
    and provides the real memory analysis + the compile-success proof).
    """
    cfg = get_config(arch)
    fd = cfg.first_dense_layers
    l_scan = cfg.n_layers - fd

    def with_unroll(mode, **kw):
        old = os.environ.get("REPRO_SCAN_UNROLL")
        os.environ["REPRO_SCAN_UNROLL"] = mode
        try:
            r, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                              variant=variant, fsdp=fsdp, **kw)
        finally:
            if old is None:
                os.environ.pop("REPRO_SCAN_UNROLL", None)
            else:
                os.environ["REPRO_SCAN_UNROLL"] = old
        return r

    r1 = with_unroll("full", extra_cfg={"n_layers": fd + 1})
    r2 = with_unroll("full", extra_cfg={"n_layers": fd + 2})
    rolled = with_unroll("1")
    if "skipped" in rolled:
        return rolled

    def combine(key):
        layer = r2[key] - r1[key]
        return rolled[key] + (l_scan - 1) * layer

    flops = combine("flops_per_device")
    bytes_acc = combine("bytes_per_device")
    wire = combine("collective_wire_bytes_per_device")
    out = dict(rolled)
    out["method"] = "composed(rolled + (L-1)*layer_delta)"
    out["variant"] = variant
    out["flops_per_device"] = flops
    out["bytes_per_device"] = bytes_acc
    out["collective_wire_bytes_per_device"] = wire
    t_c, t_m, t_x = (flops / PEAK_FLOPS, bytes_acc / HBM_BW, wire / LINK_BW)
    out["roofline"] = {
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": max((("compute", t_c), ("memory", t_m),
                         ("collective", t_x)), key=lambda kv: kv[1])[0],
    }
    chips = rolled["chips"]
    out["useful_flops_ratio"] = (out["model_flops_global"] / (flops * chips)
                                 if flops else 0.0)
    return out


def cell_path(arch, shape_name, multi_pod, variant="baseline") -> Path:
    mesh = "multi" if multi_pod else "single"
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh}__{variant}.json"


def run_cell(arch, shape_name, multi_pod, force=False, variant="baseline",
             fsdp=None, extra_cfg=None, composed=False) -> dict:
    out = cell_path(arch, shape_name, multi_pod, variant)
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists() and not force:
        return json.loads(out.read_text())
    try:
        if composed:
            result = run_composed(arch, shape_name, multi_pod=multi_pod,
                                  variant=variant, fsdp=fsdp)
        else:
            result, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                   variant=variant, fsdp=fsdp,
                                   extra_cfg=extra_cfg)
    except Exception:
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "error": traceback.format_exc(limit=8)}
    out.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--composed", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        t0 = time.perf_counter()
        r = run_cell(arch, shape, mp, force=args.force, variant=args.variant,
                     composed=args.composed)
        status = ("SKIP " + r.get("skipped", "")) if "skipped" in r else (
            "ERROR" if "error" in r else
            f"ok dom={r['roofline']['dominant']} "
            f"tc={r['roofline']['t_compute_s']:.3e} "
            f"tm={r['roofline']['t_memory_s']:.3e} "
            f"tx={r['roofline']['t_collective_s']:.3e}")
        print(f"[{time.perf_counter()-t0:7.1f}s] {arch:18s} {shape:12s} "
              f"{'2x16x16' if mp else '16x16':8s} {status}", flush=True)
        if "error" in r:
            print(r["error"], flush=True)


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

``input_specs(cfg, shape, mesh)`` returns (args, kwargs-free) SDS pytrees
with NamedShardings attached, for the step function the cell lowers:
  train   -> (params, opt_state, batch)
  prefill -> (params, batch)
  decode  -> (params, tokens, pos, cache)
No device allocation happens anywhere (params/caches via jax.eval_shape).
Modality frontends are STUBS per the assignment: whisper gets precomputed
frame embeddings, qwen2-vl gets M-RoPE positions (and its patch embeddings
would arrive pre-mixed into the token stream).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeSpec
from repro.distributed.sharding import (batch_axes, cache_specs,
                                        decode_input_specs, param_specs,
                                        train_batch_specs, zero1_specs)
from repro.models import init_cache, init_model
from repro.training.optimizer import init_opt_state

FSDP_THRESHOLD_BYTES = 4 << 30   # shard params over 'data' too beyond this


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_spec(mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Drop shardings on dims the mesh axes don't divide (e.g. whisper's
    51865 vocab over a 16-wide model axis) — those dims replicate and the
    roofline table shows the cost."""
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape) or entry is None:
            out.append(None)
            continue
        if shape[i] % _axis_size(mesh, entry) == 0:
            out.append(entry)
        elif isinstance(entry, tuple):
            # try progressively shorter prefixes of the axis tuple
            kept = None
            for j in range(len(entry) - 1, 0, -1):
                sub = entry[:j]
                if shape[i] % _axis_size(mesh, sub) == 0:
                    kept = sub
                    break
            out.append(kept)
        else:
            out.append(None)
    return P(*out)


def _sds(tree_shape, spec_tree, mesh):
    def mk(leaf, spec):
        spec = sanitize_spec(mesh, spec, leaf.shape)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, tree_shape, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def params_shape(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(init_model, cfg), key)


def needs_fsdp(cfg: ModelConfig, mesh) -> bool:
    model = mesh.shape.get("model", 1)
    bytes_per_model_shard = cfg.param_count() * 2 / model
    return bytes_per_model_shard > FSDP_THRESHOLD_BYTES


def make_param_specs(cfg: ModelConfig, mesh, *, fsdp: bool | None = None):
    pshape = params_shape(cfg)
    if fsdp is None:
        fsdp = needs_fsdp(cfg, mesh)
    if fsdp:
        return pshape, zero1_specs(cfg, pshape, mesh)   # fold 'data' in too
    return pshape, param_specs(cfg, pshape)


def _batch_spec_tree(cfg: ModelConfig, shape: ShapeSpec, mesh):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.param_dtype))
    if cfg.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    return batch


def train_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                fsdp: bool | None = None):
    pshape, pspec = make_param_specs(cfg, mesh, fsdp=fsdp)
    params = _sds(pshape, pspec, mesh)
    oshape = jax.eval_shape(init_opt_state, pshape)
    ospec = {"m": zero1_specs(cfg, pshape, mesh),
             "v": zero1_specs(cfg, pshape, mesh),
             "step": P()}
    opt = _sds(oshape, ospec, mesh)
    bspec = train_batch_specs(cfg, mesh)
    batch_shape = _batch_spec_tree(cfg, shape, mesh)
    if "positions" not in batch_shape:
        bspec.pop("positions", None)
    batch = _sds(batch_shape, bspec, mesh)
    return params, opt, batch


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                  fsdp: bool | None = None):
    pshape, pspec = make_param_specs(cfg, mesh, fsdp=fsdp)
    params = _sds(pshape, pspec, mesh)
    batch_shape = _batch_spec_tree(cfg, shape, mesh)
    batch_shape.pop("labels")
    bspec = train_batch_specs(cfg, mesh)
    bspec.pop("labels")
    if "positions" not in batch_shape:
        bspec.pop("positions", None)
    batch = _sds(batch_shape, bspec, mesh)
    return params, batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                 fsdp: bool | None = None):
    pshape, pspec = make_param_specs(cfg, mesh, fsdp=fsdp)
    params = _sds(pshape, pspec, mesh)
    b, s = shape.global_batch, shape.seq_len
    cshape = jax.eval_shape(functools.partial(init_cache, cfg, b, s))
    ba = batch_axes(mesh)
    n_batch_shards = 1
    for a in ba:
        n_batch_shards *= mesh.shape[a]
    batch1 = b < n_batch_shards
    cspec = cache_specs(cfg, mesh, batch1=batch1)
    if batch1:
        tok_spec = {"tokens": P(None, None), "pos": P(None)}
    else:
        tok_spec = decode_input_specs(cfg, mesh)
    cache = _sds(cshape, cspec, mesh)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, tok_spec["tokens"]))
    pos = jax.ShapeDtypeStruct((b,), jnp.int32,
                               sharding=NamedSharding(mesh, tok_spec["pos"]))
    return params, tokens, pos, cache

"""Training driver: data pipeline -> jitted train_step -> LSM checkpoints,
with watchdog, failure injection and elastic restart.

On real hardware this runs under the production mesh from mesh.py; on CPU
it drives the smoke configs end-to-end (examples/train_lm.py), including
the full fault path: an injected failure mid-run triggers restore from the
incremental LSM checkpoint (optionally under a DIFFERENT mesh — elastic)
and training resumes at the checkpointed step with the pipeline cursor
intact.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b \
        --smoke --steps 60 --ckpt-every 20 [--fail-at 30]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import LSMCheckpointStore
from repro.configs import get_config
from repro.data.pipeline import PipelineState, TokenPipeline
from repro.ft.watchdog import FailureInjector, InjectedFailure, StepWatchdog
from repro.models import init_model
from repro.training import AdamWConfig, init_opt_state, make_train_step


def run(arch: str, *, smoke: bool = True, steps: int = 50,
        batch: int = 8, seq: int = 64, ckpt_every: int = 20,
        ckpt_dir: str | None = None, fail_at: int | None = None,
        lr: float = 1e-3, log_every: int = 10, seed: int = 0) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    store = LSMCheckpointStore(ckpt_dir or Path("results") / "ckpt" / arch)
    injector = FailureInjector(fail_at_step=fail_at)
    watchdog = StepWatchdog()

    key = jax.random.PRNGKey(seed)
    params = init_model(cfg, key)
    opt_state = init_opt_state(params)
    pipe = TokenPipeline(cfg.vocab_size, seq, batch,
                         PipelineState(seed=seed, rank=0, world=1))
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=lr)))

    start_step = 0
    losses: list[float] = []
    restarts = 0

    def save(step):
        state = {"params": params, "opt": opt_state,
                 "pipe_cursor": np.asarray(pipe.state.cursor)}
        stats = store.save(step, state)
        return stats

    step = start_step
    while step < steps:
        try:
            batch_np = pipe.next_batch()
            if cfg.family == "encdec":
                rng = np.random.default_rng(step)
                batch_np["encoder_embeds"] = rng.standard_normal(
                    (batch, cfg.enc_seq, cfg.d_model)).astype(cfg.param_dtype)
            injector.check(step)
            watchdog.start()
            params, opt_state, metrics = step_fn(params, opt_state, batch_np)
            watchdog.stop(step)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f}", flush=True)
            if ckpt_every and step and step % ckpt_every == 0:
                st = save(step)
                print(f"  ckpt@{step}: {st['pages_written']}/"
                      f"{st['pages_total']} pages (incremental)", flush=True)
            step += 1
        except InjectedFailure as e:
            print(f"!! {e} — restoring from LSM checkpoint", flush=True)
            restarts += 1
            state_shape = jax.eval_shape(lambda: {
                "params": params, "opt": opt_state,
                "pipe_cursor": np.asarray(0)})
            restored, rstats = store.restore(treedef_like=state_shape)
            params = jax.tree.map(jax.numpy.asarray, restored["params"])
            opt_state = jax.tree.map(jax.numpy.asarray, restored["opt"])
            pipe.state.cursor = int(restored["pipe_cursor"])
            step = max(store.steps)
            print(f"   restored step {step} "
                  f"(read {rstats['segments_touched']}/"
                  f"{rstats['segments_total']} segments)", flush=True)

    final = save(steps)
    return {
        "losses": losses, "restarts": restarts,
        "stragglers": watchdog.stragglers,
        "final_ckpt": final, "index_stats": store.index_stats(),
        "store": store, "params": params,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    t0 = time.perf_counter()
    out = run(args.arch, smoke=args.smoke, steps=args.steps,
              batch=args.batch, seq=args.seq, ckpt_every=args.ckpt_every,
              fail_at=args.fail_at)
    print(f"done in {time.perf_counter()-t0:.1f}s; first loss {out['losses'][0]:.3f}"
          f" -> last {out['losses'][-1]:.3f}; restarts={out['restarts']}")


if __name__ == "__main__":
    main()

"""Production mesh factory.

(16, 16) ``("data", "model")`` per pod; the multi-pod config adds a leading
"pod" axis — (2, 16, 16) = 512 chips.  A function (not a module constant)
so importing never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant: any (data, model) factorization of the available
    devices — restore/reshard uses this after a fleet resize."""
    return jax.make_mesh(shape, axes)

#!/usr/bin/env python3
"""Offline markdown link checker: intra-repo links must resolve.

Scans markdown files for ``[text](target)`` links.  External targets
(anything with a URL scheme, ``mailto:``, or protocol-relative ``//``)
are skipped; everything else is resolved relative to the containing file
and must exist on disk.  ``#anchor`` fragments pointing into a markdown
file must match one of its headings (GitHub slug rules).  Fenced code
blocks are ignored so example snippets aren't checked.

``docs/analysis.md`` gets one extra check: the rule IDs listed in its
catalog tables must be exactly the rules registered in
``repro.analysis.catalog`` — an undocumented (or stale-documented) rule
fails like a broken link.

Usage (CI runs exactly this):

    python scripts/check_links.py README.md docs

Exits non-zero listing every broken link.
"""

from __future__ import annotations

import os
import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")
FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_~\[\]()]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def markdown_lines(path: Path):
    """Lines of ``path`` with fenced code blocks blanked out."""
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            yield ""
            continue
        yield "" if in_fence else line


def heading_slugs(path: Path) -> set[str]:
    out: set[str] = set()
    for line in markdown_lines(path):
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            out.add(github_slug(m.group(1)))
    return out


def check_file(md: Path) -> list[str]:
    errors: list[str] = []
    text = "\n".join(markdown_lines(md))
    for target in LINK_RE.findall(text):
        if SCHEME_RE.match(target) or target.startswith("//"):
            continue                       # external: not checked offline
        path_part, _, anchor = target.partition("#")
        base = md if not path_part else \
            Path(os.path.normpath(md.parent / path_part))
        if not base.exists():
            errors.append(f"{md}: broken link target {target!r}")
            continue
        if anchor and base.suffix == ".md":
            if github_slug(anchor) not in heading_slugs(base):
                errors.append(f"{md}: anchor {target!r} matches no heading "
                              f"in {base}")
    return errors


RULE_CELL_RE = re.compile(r"^\|\s*([A-Z]\d{3})\s*\|")


def check_rule_catalog(md: Path) -> list[str]:
    """docs/analysis.md only: its tables must list exactly the rule IDs
    registered in ``repro.analysis.catalog`` — no drift either way."""
    if md.name != "analysis.md":
        return []
    try:
        sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
        from repro.analysis.catalog import CATALOG
    except Exception as e:  # pragma: no cover - env without src on path
        print(f"check_links: rule-catalog check skipped ({e})")
        return []
    documented = {m.group(1) for line in markdown_lines(md)
                  if (m := RULE_CELL_RE.match(line.strip()))}
    registered = set(CATALOG)
    errors = []
    for rule in sorted(registered - documented):
        errors.append(f"{md}: registered rule {rule} missing from the "
                      f"rule tables")
    for rule in sorted(documented - registered):
        errors.append(f"{md}: rule table lists {rule}, which is not "
                      f"registered in repro.analysis.catalog")
    return errors


def main(argv: list[str]) -> int:
    args = argv or ["README.md", "docs"]
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"error: no such file or directory: {a}")
            return 2
    errors: list[str] = []
    for md in files:
        errors.extend(check_file(md))
        errors.extend(check_rule_catalog(md))
    for e in errors:
        print(e)
    print(f"check_links: {len(files)} files, "
          f"{'FAIL (' + str(len(errors)) + ' broken)' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python
"""Assert two bench-row JSON dumps are identical modulo wall-clock.

CI runs the fleet_sweep smoke twice — ``--workers 1`` and ``--workers 2``
— and pipes both dumps through this: the sweep executor's determinism
gate is that worker count may change ONLY the timing fields.  Exits 1
with a per-row diff on any other divergence.

    PYTHONPATH=src python scripts/check_row_parity.py a.json b.json
"""

from __future__ import annotations

import json
import sys

#: timing / machine-dependent keys a worker-count change may alter
VOLATILE = frozenset({
    "wall_clock_s", "fleet_wall_s", "serial_wall_s", "speedup",
    "structural_s", "temporal_s", "lindley_s", "finalize_s", "cache_hit",
    "executor_wall_s", "serial_equiv_s", "cache_hits", "cache_misses",
    "tasks", "workers",
})


def strip(row):
    if isinstance(row, dict):
        return {k: strip(v) for k, v in sorted(row.items())
                if k not in VOLATILE}
    if isinstance(row, list):
        return [strip(v) for v in row]
    return row


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    a = json.loads(open(argv[1]).read())
    b = json.loads(open(argv[2]).read())
    if len(a) != len(b):
        print(f"row-count mismatch: {argv[1]} has {len(a)}, "
              f"{argv[2]} has {len(b)}")
        return 1
    bad = 0
    for i, (ra, rb) in enumerate(zip(a, b)):
        sa, sb = strip(ra), strip(rb)
        if sa != sb:
            bad += 1
            keys = sorted(set(sa) | set(sb))
            diff = [k for k in keys if sa.get(k) != sb.get(k)]
            print(f"row {i} (bench={ra.get('bench')}) differs on {diff}")
            for k in diff[:5]:
                print(f"  {k}: {sa.get(k)!r} != {sb.get(k)!r}")
    if bad:
        print(f"PARITY FAIL: {bad}/{len(a)} rows differ beyond "
              f"volatile keys")
        return 1
    print(f"parity OK: {len(a)} rows identical modulo {len(VOLATILE)} "
          f"volatile keys")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

"""Open-loop traffic layer: parity gates and generator properties.

The load-bearing invariant: with admission disabled the open loop is a
*view* over the closed-loop engines, not a second code path —
``serve`` must be byte-identical (latencies, stalls, chain ledger) to
handing the materialized arrays to ``run`` directly, for every
registered policy on both engines.  With admission on, the verdicts are
a deterministic pre-pass, so serial and fleet engines must still agree
op for op.  The generator properties (seeded determinism, empirical
rates, over-dispersion of the bursty process, interleave order, token
bucket window cap, verdict conservation) pin the traffic layer's
statistical contract.
"""

import numpy as np
import pytest

from repro.core import (DeviceModel, FleetEngine, Simulator, get_policy,
                        reset_uid_counters)
from repro.serving import (ADMIT, SHED, THROTTLE, AdmissionConfig,
                           TenantSpec, TokenBucket, TrafficSpec,
                           bursty_arrivals, materialize, poisson_arrivals,
                           serve, serve_grid)

SCALE = 1 << 17
DEV = DeviceModel.scaled(1 / 1024)
POLICIES = ("vlsm", "rocksdb", "rocksdb_io", "adoc", "lsmi", "lazy")


def _one_tenant_spec(arrival="deterministic", admission=None, seed=11):
    return TrafficSpec(
        tenants=(TenantSpec("t0", rate_ops_s=3_000.0, mix="ycsb_a",
                            arrival=arrival, priority=1, slo_ms=50.0),),
        duration_s=1.2, population=3_000, seed=seed, settle_s=5.0,
        admission=admission)


def _shedding_spec(seed=11):
    """Three tenants hot enough to trip both throttling and shedding."""
    return TrafficSpec(
        tenants=(
            TenantSpec("prio", rate_ops_s=400.0, mix="ycsb_b",
                       arrival="poisson", priority=0, slo_ms=25.0),
            TenantSpec("mid", rate_ops_s=1_500.0, mix="ycsb_a",
                       arrival="bursty", priority=1, slo_ms=50.0,
                       limit_ops_s=1_200.0, burst_ops=32.0),
            TenantSpec("bulk", rate_ops_s=2_500.0, mix="load",
                       arrival="poisson", priority=2, slo_ms=200.0),
        ),
        duration_s=1.2, population=3_000, seed=seed, settle_s=5.0,
        admission=AdmissionConfig(max_queue_delay_s=0.02))


def _chain_ledger(engine):
    """The per-shard chain ledger, as comparable tuples."""
    return [[(c.chain_id, c.trigger, c.length, c.width, c.width_bytes,
              c.n_jobs, round(c.t_start, 12), round(c.t_finish, 12),
              round(c.stall_s, 12)) for c in st.chains]
            for st in engine.shard_stats]


# ------------------------------------------------- closed↔open parity

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("engine_cls", (Simulator, FleetEngine),
                         ids=("serial", "fleet"))
def test_closed_open_parity(policy, engine_cls):
    """Deterministic arrivals, one tenant, admission disabled: ``serve``
    is byte-identical to ``run`` on the same materialized arrays —
    latencies, stall events, and the chain ledger."""
    cfg = get_policy(policy).default_config(scale=SCALE).with_(n_shards=2)
    spec = _one_tenant_spec("deterministic")
    stream = materialize(spec)

    reset_uid_counters()
    closed = engine_cls(cfg, DEV)
    r_closed = closed.run(stream.op_types, stream.keys, stream.arrivals,
                          stream.scan_lens)

    reset_uid_counters()
    open_ = engine_cls(cfg, DEV)
    sr = open_.serve(spec)

    assert np.array_equal(sr.res.latency, r_closed.latency)
    assert sr.res.stall_events == r_closed.stall_events
    assert sr.res.n_stalls == r_closed.n_stalls
    assert np.array_equal(sr.res.get_reads, r_closed.get_reads)
    assert _chain_ledger(open_) == _chain_ledger(closed)
    # and the ledgers account for every offered op as admitted
    (led,) = sr.tenants
    assert led.ops_offered == led.ops_admitted == stream.n_offered
    assert led.ops_shed == led.ops_throttled == 0


@pytest.mark.parametrize("policy", ("vlsm", "rocksdb"))
def test_fleet_matches_serial_under_shedding(policy):
    """Poisson arrivals + active admission: both engines receive the
    same admitted stream (verdicts byte-equal) and agree on it."""
    cfg = get_policy(policy).default_config(scale=SCALE).with_(n_shards=2)
    spec = _shedding_spec()

    reset_uid_counters()
    sr_ser = Simulator(cfg, DEV).serve(spec)
    reset_uid_counters()
    sr_fle = FleetEngine(cfg, DEV).serve(spec)

    assert np.array_equal(sr_ser.verdicts, sr_fle.verdicts)
    assert sr_ser.shed_frac > 0.0          # the controller actually acted
    assert sr_ser.throttled_frac > 0.0     # ...and so did a token bucket
    assert sr_ser.res.stall_events == sr_fle.res.stall_events
    assert float(np.max(np.abs(sr_fle.res.latency
                               - sr_ser.res.latency))) < 1e-9
    assert [t.summary() for t in sr_ser.tenants] \
        == [t.summary() for t in sr_fle.tenants]


def test_serve_grid_matches_per_factor_serve():
    """The amortized admission-off grid (one structural replay, one
    temporal pass per factor) equals fresh per-factor serial serves."""
    cfg = get_policy("vlsm").default_config(scale=SCALE).with_(n_shards=2)
    spec = _one_tenant_spec("poisson")
    factors = (0.5, 2.0)
    grid = serve_grid(cfg, DEV, spec, factors)
    for f, sr_grid in zip(factors, grid):
        reset_uid_counters()
        sr = Simulator(cfg, DEV).serve(spec, load_factor=f)
        assert float(np.max(np.abs(sr_grid.res.latency
                                   - sr.res.latency))) < 1e-9
        assert sr_grid.res.stall_events == sr.res.stall_events


# ------------------------------------------------ generator properties

def test_materialize_is_deterministic():
    spec = _shedding_spec()
    a, b = materialize(spec), materialize(spec)
    for f in ("op_types", "keys", "arrivals", "scan_lens", "tenant_ids",
              "tenant_seq"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    c = materialize(TrafficSpec(**{**vars(spec), "seed": spec.seed + 1}))
    assert not np.array_equal(a.arrivals, c.arrivals)


def test_poisson_empirical_rate():
    rng = np.random.default_rng(0)
    rate, n = 2_000.0, 40_000
    arr = poisson_arrivals(n, rate, rng)
    assert np.all(np.diff(arr) > 0)
    emp = n / arr[-1]
    assert abs(emp - rate) / rate < 0.05


def test_bursty_overdispersed_vs_poisson():
    """Index of dispersion of windowed counts: the on-off superposition
    must be over-dispersed relative to Poisson at the same mean rate."""
    rate, n, win_s = 2_000.0, 40_000, 0.05

    def iod(arr):
        t_end = arr[-1]
        counts = np.bincount((arr / win_s).astype(np.int64),
                             minlength=int(t_end / win_s))[:-1]
        return counts.var() / counts.mean()

    iod_p = iod(poisson_arrivals(n, rate, np.random.default_rng(1)))
    iod_b = iod(bursty_arrivals(n, rate, np.random.default_rng(1)))
    assert iod_p < 2.0                 # Poisson: IoD ≈ 1
    assert iod_b > 2.0 * iod_p         # bursty: clearly over-dispersed


def test_interleave_preserves_order():
    """Global stream is arrival-sorted; within each tenant the generated
    sequence order survives the interleave (stable sort invariant)."""
    stream = materialize(_shedding_spec())
    assert np.all(np.diff(stream.arrivals) >= 0)
    for ti in np.unique(stream.tenant_ids[stream.tenant_ids >= 0]):
        seq = stream.tenant_seq[stream.tenant_ids == ti]
        assert np.all(np.diff(seq) == 1)
        per_tenant_arr = stream.arrivals[stream.tenant_ids == ti]
        assert np.all(np.diff(per_tenant_arr) >= 0)


def test_token_bucket_window_cap():
    """Over any window the bucket admits at most burst + rate * span."""
    rng = np.random.default_rng(5)
    rate, burst = 100.0, 8.0
    times = np.sort(rng.uniform(0.0, 4.0, size=3_000))
    bucket = TokenBucket(rate_ops_s=rate, burst_ops=burst)
    admitted = np.array([bucket.try_admit(float(t)) for t in times])
    t_adm = times[admitted]
    assert t_adm.shape[0] <= burst + rate * times[-1]
    # sliding windows, not just the full span
    for w in (0.1, 0.5, 1.0):
        counts = np.array([((t_adm >= t) & (t_adm < t + w)).sum()
                           for t in np.arange(0.0, 4.0 - w, w / 2)])
        assert counts.max() <= burst + rate * w + 1
    # disabled bucket admits everything
    assert all(TokenBucket(0.0).try_admit(float(t)) for t in times)


def test_verdict_conservation():
    """admitted + shed + throttled == offered, per tenant and globally
    (also re-asserted at runtime by the paranoid checks in serve)."""
    cfg = get_policy("vlsm").default_config(scale=SCALE).with_(n_shards=2)
    assert cfg.paranoid_checks          # conftest exports the env knob
    reset_uid_counters()
    sr = Simulator(cfg, DEV).serve(_shedding_spec())
    for led in sr.tenants:
        assert led.ops_admitted + led.ops_shed + led.ops_throttled \
            == led.ops_offered
    n_verdicts = np.bincount(sr.verdicts[sr.stream.tenant_ids >= 0],
                             minlength=3)
    assert n_verdicts.sum() == sr.offered_ops
    assert n_verdicts[SHED] == sum(t.ops_shed for t in sr.tenants)
    assert n_verdicts[THROTTLE] == sum(t.ops_throttled for t in sr.tenants)
    # shed/throttled ops never reached the engine, admitted all did
    assert sr.res.latency.shape[0] \
        == int((sr.verdicts == ADMIT).sum())
    # priority ordering: the floor tenant is never shed
    assert sr.tenants[0].ops_shed == 0
    assert sr.tenants[2].shed_frac >= sr.tenants[1].shed_frac


def test_admission_counters_land_in_stats():
    """Per-(tenant, shard) ledgers and scalar counters ride the engine's
    Stats, so FleetStats-style aggregation sees admission like any other
    counter."""
    cfg = get_policy("vlsm").default_config(scale=SCALE).with_(n_shards=2)
    reset_uid_counters()
    sim = Simulator(cfg, DEV)
    sr = sim.serve(_shedding_spec())
    total_offered = sum(st.ops_offered for st in sim.shard_stats)
    assert total_offered == sr.offered_ops
    assert sum(st.ops_shed for st in sim.shard_stats) \
        == sum(t.ops_shed for t in sr.tenants)
    merged = {}
    for st in sim.shard_stats:
        for name, led in st.tenants.items():
            if name in merged:
                merged[name].merge_from(led)
            else:
                import dataclasses
                merged[name] = dataclasses.replace(led)
        if st.ops_offered:
            assert "per_tenant" in st.summary()
    for led, glob in zip(sr.tenants, merged.values()):
        assert led.summary() == glob.summary()


@pytest.mark.slow
def test_full_serve_matrix():
    """The un-quick serve matrix: every registered policy × the full
    factor axis × both admission arms.  Past the knee, every policy's
    admission arm sheds (never the priority-0 tenant) and beats the
    open loop's priority-0 tail.  Excluded from the default run
    (pyproject addopts); ``pytest -m slow``."""
    from repro.bench_kv.db_bench import SERVE_FACTORS, serve_sweep_bench
    from repro.core.policies import names as policy_names
    rows = serve_sweep_bench(list(policy_names()),
                             duration_s=4.0, population=8_000,
                             factors=SERVE_FACTORS)
    assert len(rows) == len(policy_names()) * 2 * len(SERVE_FACTORS)
    top = max(SERVE_FACTORS)
    for nm in policy_names():
        arm = {r["admission"]: r for r in rows
               if r["policy"] == nm and r["load_factor"] == top}
        prio_on = next(t for t in arm["on"]["per_tenant"]
                       if t["priority"] == 0)
        prio_off = next(t for t in arm["off"]["per_tenant"]
                        if t["priority"] == 0)
        assert arm["off"]["shed_frac"] == 0.0
        assert arm["on"]["shed_frac"] > 0.1, nm
        assert prio_on["shed_frac"] == 0.0, nm
        assert prio_on["p999_ms"] <= prio_off["p999_ms"], nm
        assert prio_on["slo_violation_frac"] \
            <= prio_off["slo_violation_frac"], nm


# --------------------------------------------------- the pinned knee

def test_admission_prevents_collapse_past_knee():
    """The acceptance scenario (db_bench's pinned serve_sweep spec) at a
    past-knee load factor: open loop collapses (the priority-0 tenant
    blows its SLO), admission sheds low-priority work instead
    (shed_frac > 0) and keeps the priority-0 tail bounded."""
    from repro.bench_kv.db_bench import make_serve_spec
    cfg = get_policy("vlsm").default_config(scale=1 << 18).with_(n_shards=2)
    dev = DeviceModel.scaled((1 << 18) / (64 << 20))

    reset_uid_counters()
    off = Simulator(cfg, dev).serve(
        make_serve_spec(duration_s=1.5, population=3_000, admission=False),
        load_factor=3.0)
    reset_uid_counters()
    on = Simulator(cfg, dev).serve(
        make_serve_spec(duration_s=1.5, population=3_000, admission=True),
        load_factor=3.0)

    prio_off, prio_on = off.tenants[0], on.tenants[0]
    p999_off = float(np.percentile(off.tenant_latency(0), 99.9)) * 1e3
    p999_on = float(np.percentile(on.tenant_latency(0), 99.9)) * 1e3
    # open loop: past the knee the high-priority tail is SLO-busted
    assert off.shed_frac == 0.0
    assert p999_off > 2 * prio_off.slo_ms
    assert prio_off.slo_violation_frac > 0.2
    # admission: real shedding, priority-0 never shed, tail bounded
    assert on.shed_frac > 0.1
    assert prio_on.ops_shed == 0
    assert p999_on < p999_off / 2
    assert prio_on.slo_violation_frac < 0.05
    assert on.goodput_ops_s > 0.5 * off.goodput_ops_s

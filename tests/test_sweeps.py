"""Sweep executor + structural-replay cache: determinism and reuse.

Three contracts pinned here:

* **Fork determinism** — ``sweep_execute`` with ``workers=4`` returns
  byte-identical results to ``workers=1`` AND to the legacy
  single-process ``fleet_sweep`` path, across every registered policy.
  The mechanism is the per-engine :class:`UidNamespace`: a fresh
  namespace reproduces exactly the uid streams ``reset_uid_counters()``
  rewinds the module counters to, so worker scheduling cannot perturb
  bloom seeding.
* **Cache soundness** — a :class:`StructuralCache` hit skips phase A
  and still returns bit-identical :class:`SimResult`\\ s to a fresh
  replay; the content key covers config, device, regions and op stream
  (a change to any of them misses) but NOT arrivals (every schedule
  shares the entry — that independence is the amortization).
* **Pad-plan reuse** — ``lindley_batch_np`` reuses its power-of-two
  bucketing plan and padded buffers across calls with the same length
  multiset, without leaking one call's payload into the next.
"""

import numpy as np
import pytest

from repro.core import (DeviceModel, Simulator, StructuralCache, SweepPoint,
                        UidNamespace, fleet_sweep, get_policy, point_key,
                        reset_uid_counters, run_point, serial_sweep,
                        serial_sweep_parallel, sweep_execute)
from repro.core.policies import resolve_names

SCALE = 1 << 17
DEV = DeviceModel.scaled(1 / 1024)
POLICIES = resolve_names("all")


def _workload(seed=3, n=5_000, read_frac=0.3):
    rng = np.random.default_rng(seed)
    ops = (rng.random(n) < read_frac).astype(np.uint8)
    keys = rng.integers(0, SCALE, n).astype(np.int64)
    return ops, keys


def _points(policies, shard_counts=(1,), rates=(3_000.0, 12_000.0), n=5_000):
    ops, keys = _workload(n=n)
    grid = [np.arange(n, dtype=np.float64) / r for r in rates]
    return [SweepPoint(label=f"{p}/{k}",
                       cfg=get_policy(p).default_config(scale=SCALE)
                       .with_(n_shards=k),
                       device=DEV, op_types=ops, keys=keys,
                       arrivals_grid=grid)
            for p in policies for k in shard_counts]


def _assert_identical(a, b):
    """Byte-identity, not tolerance: same uid streams, same arithmetic."""
    assert np.array_equal(a.latency, b.latency)
    assert np.array_equal(a.get_reads, b.get_reads)
    assert np.array_equal(a.get_probed, b.get_probed)
    assert a.n_stalls == b.n_stalls
    assert a.stall_events == b.stall_events


# ------------------------------------------------------ fork determinism

def test_workers_byte_parity_all_policies():
    """Every registered policy through the executor: workers=4 equals
    workers=1 equals the legacy fleet_sweep path, byte for byte."""
    points = _points(POLICIES)
    r1, t1 = sweep_execute(points, workers=1)
    r4, t4 = sweep_execute(points, workers=4)
    legacy = fleet_sweep(points, backend="numpy")
    assert len(r1) == len(r4) == len(legacy) == len(points)
    for p1, p4, pl in zip(r1, r4, legacy):
        for a, b, c in zip(p1, p4, pl):
            _assert_identical(a, b)
            _assert_identical(a, c)
    assert [t.label for t in t1] == [t.label for t in t4] \
        == [p.label for p in points]


def test_serial_sweep_parallel_matches_serial_sweep():
    """The heap-loop oracle under the pool: namespace-built engines over
    flattened (point, rate) tasks reproduce serial_sweep exactly."""
    points = _points(("vlsm", "rocksdb"), shard_counts=(1, 2))
    sp1 = serial_sweep_parallel(points, workers=1)
    sp4 = serial_sweep_parallel(points, workers=4)
    legacy = serial_sweep(points)
    for g1, g4, gl in zip(sp1, sp4, legacy):
        assert len(g1) == len(g4) == len(gl)
        for a, b, c in zip(g1, g4, gl):
            _assert_identical(a, b)
            _assert_identical(a, c)


def test_namespace_equals_reset_counters():
    """The foundation: a fresh UidNamespace reproduces the module-counter
    stream reset_uid_counters() rewinds to — same blooms, same bytes."""
    cfg = get_policy("vlsm").default_config(scale=SCALE).with_(n_shards=2)
    ops, keys = _workload()
    arr = np.arange(ops.shape[0], dtype=np.float64) / 5_000.0
    reset_uid_counters()
    r_mod = Simulator(cfg, DEV).run(ops, keys, arr)
    r_ns = Simulator(cfg, DEV, uids=UidNamespace()).run(ops, keys, arr)
    _assert_identical(r_mod, r_ns)


# -------------------------------------------------------- cache keying

def test_point_key_ignores_arrivals_and_label():
    points = _points(("vlsm",))
    alt = _points(("vlsm",), rates=(7_000.0,))
    alt[0].label = "renamed"
    assert point_key(points[0]) == point_key(alt[0])


def test_point_key_covers_cfg_device_and_stream():
    base = _points(("vlsm",))[0]
    k0 = point_key(base)

    recfg = _points(("vlsm",), shard_counts=(2,))[0]
    assert point_key(recfg) != k0

    other_policy = _points(("rocksdb",))[0]
    assert point_key(other_policy) != k0

    redev = SweepPoint(label=base.label, cfg=base.cfg,
                       device=DeviceModel.scaled(1 / 2048),
                       op_types=base.op_types, keys=base.keys,
                       arrivals_grid=base.arrivals_grid)
    assert point_key(redev) != k0

    rekeys = SweepPoint(label=base.label, cfg=base.cfg, device=DEV,
                        op_types=base.op_types,
                        keys=(base.keys + 1).astype(np.int64),
                        arrivals_grid=base.arrivals_grid)
    assert point_key(rekeys) != k0


def test_cache_hit_misses_and_invalidation():
    cache = StructuralCache()
    pt = _points(("vlsm",))[0]
    _, t_miss = run_point(pt, cache=cache)
    assert not t_miss.cache_hit and t_miss.structural_s > 0.0
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 0

    _, t_hit = run_point(pt, cache=cache)
    assert t_hit.cache_hit and t_hit.structural_s == 0.0
    assert cache.stats()["hits"] == 1

    # a config change is a different content address: fresh phase A
    recfg = _points(("vlsm",), shard_counts=(2,))[0]
    _, t2 = run_point(recfg, cache=cache)
    assert not t2.cache_hit
    assert cache.stats()["misses"] == 2 and len(cache) == 2

    # a stream change likewise
    restream = SweepPoint(label=pt.label, cfg=pt.cfg, device=DEV,
                          op_types=pt.op_types,
                          keys=(pt.keys + 1).astype(np.int64),
                          arrivals_grid=pt.arrivals_grid)
    _, t3 = run_point(restream, cache=cache)
    assert not t3.cache_hit and len(cache) == 3


def test_cache_hit_is_bit_identical_to_fresh_replay():
    """The correctness gate: a cached engine's temporal passes return
    the exact results a fresh structural replay would."""
    cache = StructuralCache()
    pt = _points(("vlsm",), shard_counts=(2,))[0]
    miss_res, _ = run_point(pt, cache=cache)
    hit_res, t = run_point(pt, cache=cache)
    assert t.cache_hit
    fresh_res, _ = run_point(pt, cache=None)
    for a, b, c in zip(hit_res, miss_res, fresh_res):
        _assert_identical(a, b)
        _assert_identical(a, c)


def test_cache_lru_eviction():
    cache = StructuralCache(maxsize=2)
    pts = _points(("vlsm", "rocksdb", "lazy"), n=2_000)
    keys = [point_key(p) for p in pts]
    for p in pts[:2]:
        run_point(p, cache=cache)
    run_point(pts[0], cache=cache)           # refresh pts[0]'s recency
    run_point(pts[2], cache=cache)           # evicts pts[1], the LRU
    assert len(cache) == 2
    assert keys[0] in cache and keys[2] in cache
    assert keys[1] not in cache


# ----------------------------------------------------- pad-plan caching

def test_lindley_pad_plan_reused_across_calls():
    from repro.kernels.lindley_scan import ops as lops
    lops.clear_pad_plans()
    lens = (700, 700, 300, 90)
    rng = np.random.default_rng(5)
    svc = [rng.random(n) for n in lens]
    arr = [np.sort(rng.random(n)) * 10 for n in lens]
    plan_a = lops._pad_plan(lens)
    out1 = lops.lindley_batch_np(arr, svc, backend="jnp")
    plan_b = lops._pad_plan(lens)
    assert plan_a is plan_b                  # LRU returns the same plan

    # second call with DIFFERENT payloads through the same buffers:
    # no state leaks — each departure equals its own fresh computation
    svc2 = [rng.random(n) for n in lens]
    arr2 = [np.sort(rng.random(n)) * 10 for n in lens]
    out2 = lops.lindley_batch_np(arr2, svc2, backend="jnp")
    lops.clear_pad_plans()
    fresh2 = lops.lindley_batch_np(arr2, svc2, backend="jnp")
    fresh1 = lops.lindley_batch_np(arr, svc, backend="jnp")
    for got, want in zip(out2 + out1, fresh2 + fresh1):
        assert np.array_equal(got, want)


def test_lindley_numpy_scratch_growth():
    from repro.kernels.lindley_scan import ops as lops
    rng = np.random.default_rng(9)
    small = [rng.random(50) for _ in range(3)]
    arr_s = [np.sort(rng.random(50)) * 10 for _ in range(3)]
    big = [rng.random(5_000)]
    arr_b = [np.sort(rng.random(5_000)) * 10]
    o_small = lops.lindley_batch_np(arr_s, small, backend="numpy")
    o_big = lops.lindley_batch_np(arr_b, big, backend="numpy")
    o_small2 = lops.lindley_batch_np(arr_s, small, backend="numpy")
    for got, want in zip(o_small, o_small2):
        assert np.array_equal(got, want)
    assert o_big[0].shape == (5_000,)

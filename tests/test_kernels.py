"""Per-kernel allclose vs pure-jnp oracles, shape/dtype sweeps
(interpret=True — kernel bodies execute on CPU; TPU is the target)."""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")
import jax  # noqa: E402


# ------------------------------------------------------------- merge_path
@pytest.mark.parametrize("n,m", [(1, 1), (7, 130), (128, 128), (257, 511),
                                 (1000, 2500)])
def test_merge_path(n, m):
    from repro.kernels.merge_path import ops
    rng = np.random.default_rng(n * 1000 + m)
    a = np.sort(rng.integers(-2**46, 2**46, n).astype(np.int64))
    b = np.sort(rng.integers(-2**46, 2**46, m).astype(np.int64))
    if n > 2 and m > 2:
        b[:2] = a[:2]
        b = np.sort(b)
    asq = np.arange(n, dtype=np.int64)
    bsq = np.arange(n, n + m, dtype=np.int64)
    k, s = ops.merge_two_runs_np(a, asq, b, bsq)
    kk = np.concatenate([a, b]); ss = np.concatenate([asq, bsq])
    order = np.argsort(kk, kind="stable")
    assert np.array_equal(k, kk[order])
    assert np.array_equal(s, ss[order])


def test_merge_path_planes_roundtrip():
    from repro.kernels.merge_path.ops import join_planes, split_planes
    rng = np.random.default_rng(0)
    keys = rng.integers(-2**62, 2**62, 1000).astype(np.int64)
    hi, lo = split_planes(keys)
    assert np.array_equal(join_planes(hi, lo), keys)
    # order preservation under (hi, lo) lexicographic compare
    order = np.lexsort((lo.astype(np.int64), hi.astype(np.int64)))
    assert np.array_equal(keys[order], np.sort(keys))


# ------------------------------------------------------------ overlap_scan
@pytest.mark.parametrize("nf,nk", [(1, 5), (130, 7), (640, 1000)])
def test_overlap_scan(nf, nk):
    from repro.kernels.overlap_scan import ops
    rng = np.random.default_rng(nf + nk)
    f = np.sort(rng.integers(-2**45, 2**45, nf).astype(np.int64))
    k = rng.integers(-2**45, 2**45, nk).astype(np.int64)
    k[: min(nf, nk) // 2] = f[: min(nf, nk) // 2]
    got = ops.fence_rank_np(f, k)
    assert np.array_equal(got, np.searchsorted(f, k, side="right"))


# ------------------------------------------------------------ lindley_scan
@pytest.mark.parametrize("n,rho,d0", [
    (1, 0.5, None), (7, 0.9, None), (128, 1.1, None), (257, 0.8, 3.0),
    (1000, 1.05, None), (513, 0.0, 12.5),
])
def test_lindley_scan(n, rho, d0):
    """All three backends vs the monolithic numpy recursion (the DES's
    own accounting pass), across under/over-saturated queues and
    carried-in clocks.  Tolerance is f64 roundoff of the blocked
    cumsum."""
    from repro.kernels.lindley_scan import ops
    rng = np.random.default_rng(n + int(rho * 10))
    service = rng.exponential(1e-6, n) if rho > 0 else np.zeros(n)
    mean_s = max(service.mean(), 1e-12)
    arrivals = np.cumsum(rng.exponential(mean_s / max(rho, 1e-3), n))
    arrivals += 100.0          # DES-scale absolute times vs us latencies
    want = ops.lindley_numpy(service, arrivals,
                             d0=d0 if d0 is not None else float("-inf"))
    for backend in ("jnp", "pallas", "numpy"):
        got = ops.lindley_np(service, arrivals,
                             d0=d0 if d0 is not None else float("-inf"),
                             backend=backend)
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    # departures are monotone and never precede arrival + service
    assert np.all(np.diff(want) >= -1e-15)
    assert np.all(want >= arrivals + service - 1e-9)


def test_lindley_scan_batched_ragged():
    from repro.kernels.lindley_scan import ops
    rng = np.random.default_rng(0)
    lens = [0, 1, 130, 512, 77]
    services = [rng.exponential(2e-6, L) for L in lens]
    arrivals = [np.cumsum(rng.exponential(1.5e-6, L)) + 50.0 for L in lens]
    d0 = [float("-inf"), 50.0, float("-inf"), 51.0, float("-inf")]
    for backend in ("pallas", "jnp", "numpy"):
        got = ops.lindley_batch_np(services, arrivals, d0, backend=backend)
        assert len(got) == len(lens)
        for g, s, a, c in zip(got, services, arrivals, d0):
            np.testing.assert_allclose(g, ops.lindley_numpy(s, a, c),
                                       rtol=1e-12, atol=1e-12)


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("b,hq,hkv,s,d,win,dtype", [
    (1, 2, 2, 256, 64, None, "float32"),
    (2, 4, 2, 128, 64, None, "float32"),
    (1, 2, 1, 256, 128, 128, "float32"),
    (1, 2, 2, 384, 64, None, "bfloat16"),
    (1, 1, 1, 130, 64, None, "float32"),
])
def test_flash_attention(b, hq, hkv, s, d, win, dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    rng = np.random.default_rng(42)
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), dt)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dt)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dt)
    got = flash_attention(q, k, v, causal=True, window=win)
    ref = attention_ref(q, k, v, causal=True, window=win)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


# --------------------------------------------------------- paged_attention
@pytest.mark.parametrize("b,hq,hkv,d,npg,ps,maxp,dtype", [
    (2, 4, 2, 64, 16, 16, 4, "float32"),
    (1, 8, 1, 128, 32, 32, 8, "float32"),
    (3, 4, 4, 64, 8, 16, 3, "bfloat16"),
])
def test_paged_attention(b, hq, hkv, d, npg, ps, maxp, dtype):
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    rng = np.random.default_rng(7)
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dt)
    kp = jnp.asarray(rng.standard_normal((npg, ps, hkv, d)), dt)
    vp = jnp.asarray(rng.standard_normal((npg, ps, hkv, d)), dt)
    pt = jnp.asarray(rng.integers(0, npg, (b, maxp)), jnp.int32)
    ln = jnp.asarray(rng.integers(1, maxp * ps + 1, (b,)), jnp.int32)
    got = paged_attention(q, kp, vp, pt, ln)
    ref = paged_attention_ref(q, kp, vp, pt, ln)
    tol = 5e-2 if dtype == "bfloat16" else 2e-5
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


# ---------------------------------------------------------------- ssd_scan
@pytest.mark.parametrize("b,L,h,g,p,n,ck,dtype", [
    (1, 128, 2, 1, 64, 64, 64, "float32"),
    (2, 256, 4, 2, 32, 16, 128, "float32"),
    (1, 200, 2, 1, 64, 32, 64, "float32"),
    (1, 128, 2, 1, 64, 64, 64, "bfloat16"),
])
def test_ssd_scan(b, L, h, g, p, n, ck, dtype):
    from repro.kernels.ssd_scan import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    rng = np.random.default_rng(4)
    dt = jnp.dtype(dtype)
    x = jnp.asarray(rng.standard_normal((b, L, h, p)), dt)
    dts = jnp.asarray(np.abs(rng.standard_normal((b, L, h))) * 0.1 + 0.01, dt)
    a = jnp.asarray(-np.abs(rng.standard_normal(h)) - 0.1, jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, L, g, n)) * 0.3, dt)
    cc = jnp.asarray(rng.standard_normal((b, L, g, n)) * 0.3, dt)
    got = ssd_scan(x, dts, a, bb, cc, ck=ck)
    rep = h // g
    bf = jnp.repeat(bb, rep, axis=2); cf = jnp.repeat(cc, rep, axis=2)
    ref = ssd_scan_ref(
        x.transpose(0, 2, 1, 3).reshape(b * h, L, p),
        dts.transpose(0, 2, 1).reshape(b * h, L),
        jnp.tile(a, b),
        bf.transpose(0, 2, 1, 3).reshape(b * h, L, n),
        cf.transpose(0, 2, 1, 3).reshape(b * h, L, n),
    ).reshape(b, h, L, p).transpose(0, 2, 1, 3)
    tol = 6e-2 if dtype == "bfloat16" else 2e-4
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol

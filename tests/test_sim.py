"""DES + open-loop harness behaviour (the §5 methodology)."""

import numpy as np

from repro.bench_kv import make_load_a, make_run_a, run_ycsb
from repro.core import DeviceModel, LSMConfig, Simulator

SCALE = 1 << 18
LAM = SCALE / (64 << 20)


def _load(cfg, n=60_000, rate=2e3):
    spec = make_load_a(n)
    return run_ycsb(cfg, spec, rate=rate, scale=SCALE)


def test_lindley_latency_exact_small():
    """Hand-checkable queue: 3 ops, constant service, one burst."""
    cfg = LSMConfig.vlsm_default(scale=SCALE)
    sim = Simulator(cfg, DeviceModel.scaled(LAM))
    ops = np.zeros(3, np.uint8)
    keys = np.array([1, 2, 3], np.int64)
    arr = np.array([0.0, 0.0, 10.0])
    res = sim.run(ops, keys, arr)
    from repro.core.sim import PUT_SERVICE
    np.testing.assert_allclose(res.latency[0], PUT_SERVICE, rtol=1e-6)
    np.testing.assert_allclose(res.latency[1], 2 * PUT_SERVICE, rtol=1e-6)
    np.testing.assert_allclose(res.latency[2], PUT_SERVICE, rtol=1e-6)


def test_vlsm_beats_rocksdb_on_stalls_and_p99():
    """The paper's headline, measured per its §5 methodology: each system
    is driven at the SAME FRACTION (60%) of its own sustainable throughput
    (profiling run first); vLSM's stalls/P99 stay far below RocksDB's."""
    from repro.bench_kv import sustainable_throughput
    spec = make_load_a(60_000)
    cfg_v = LSMConfig.vlsm_default(scale=SCALE)
    cfg_r = LSMConfig.rocksdb_io_default(scale=SCALE)
    r_v = run_ycsb(cfg_v, spec,
                   0.6 * sustainable_throughput(cfg_v, spec, scale=SCALE),
                   scale=SCALE)
    r_r = run_ycsb(cfg_r, spec,
                   0.6 * sustainable_throughput(cfg_r, spec, scale=SCALE),
                   scale=SCALE)
    assert r_v.sim.stall_max <= r_r.sim.stall_max
    assert r_v.sim.p99 <= r_r.sim.p99
    # RocksDB-IO chains are much wider (tiering)
    assert (r_r.sim.stats.max_chain_width
            > 3 * r_v.sim.stats.max_chain_width)


def test_adoc_between():
    rate = 2500.0
    r_a = _load(LSMConfig.adoc_default(scale=SCALE), rate=rate)
    r_r = _load(LSMConfig.rocksdb_io_default(scale=SCALE), rate=rate)
    assert r_a.sim.stall_total <= r_r.sim.stall_total


def test_mixed_read_write_reads_measured():
    cfg = LSMConfig.vlsm_default(scale=SCALE)
    pop = np.unique(np.random.default_rng(0).integers(
        0, 2**40, 30_000).astype(np.int64))
    spec = make_run_a(pop, 20_000)
    res = run_ycsb(cfg, spec, rate=3e3, scale=SCALE, preload=pop)
    gets = res.sim.op_types == 1
    assert gets.sum() > 0
    assert res.sim.pct(99, op=1) > 0.0
    assert res.sim.stats.device_reads > 0


def test_regions_shorten_chains():
    """Fig 10: more regions -> shorter chains (fewer levels per region)."""
    cfg = LSMConfig.rocksdb_io_default(scale=SCALE)
    spec = make_load_a(80_000)
    r1 = run_ycsb(cfg, spec, rate=3e3, scale=SCALE, n_regions=1)
    r4 = run_ycsb(cfg, spec, rate=3e3, scale=SCALE, n_regions=4)
    assert (r4.sim.stats.mean_chain_width
            <= r1.sim.stats.mean_chain_width + 1e-9)


def test_db_bench_fillrandom():
    """db_bench driver: fills multiple levels, reports amplification."""
    from repro.bench_kv.db_bench import fillrandom
    cfg = LSMConfig.vlsm_default(scale=1 << 17)
    row = fillrandom(cfg, 30_000, dist="uniform", scale=1 << 17)
    assert row["levels_filled"] >= 3
    assert row["io_amp"] > 1.0
    row_p = fillrandom(cfg, 30_000, dist="pareto", scale=1 << 17)
    # skew -> updates die young -> less amplification (paper Fig 13c)
    assert row_p["io_amp"] <= row["io_amp"]


# ----------------------------------------------------------------- Lindley
# edge cases (heap loop vs the batched paths on the shapes that break
# naive window accounting; see repro.core.fleet for the aggregates)

def _engine_parity(cfg, ops, keys, arr):
    """Serial heap loop vs the two-phase fleet engine, op for op."""
    from repro.core import FleetEngine, reset_uid_counters
    dev = DeviceModel.scaled(LAM)
    reset_uid_counters()
    r_ser = Simulator(cfg, dev).run(ops, keys, arr)
    reset_uid_counters()
    r_fle = FleetEngine(cfg, dev).run(ops, keys, arr)
    assert np.array_equal(r_ser.get_reads, r_fle.get_reads)
    assert r_ser.n_stalls == r_fle.n_stalls
    assert float(np.max(np.abs(r_ser.latency - r_fle.latency))) < 1e-9
    return r_ser


def test_lindley_empty_shard_windows():
    """Shards no key routes to: zero windows, empty Lindley queues in the
    vectorized path, and nothing in the serial heap — identical either way."""
    cfg = LSMConfig.vlsm_default(scale=SCALE).with_(n_shards=8)
    n = 2_000
    ops = np.zeros(n, np.uint8)
    keys = np.full(n, 7, np.int64)        # ONE key: 7 of 8 shards idle
    arr = np.arange(n, dtype=np.float64) / 3e3
    _engine_parity(cfg, ops, keys, arr)


def test_lindley_single_op_windows():
    """memtable_size == kv_size -> keys_per_memtable == 1: every write is
    its own fill window (wsum = one op's service, wmax = its slack), the
    densest possible event schedule."""
    base = LSMConfig.vlsm_default(scale=SCALE)
    cfg = base.with_(memtable_size=base.kv_size)
    assert cfg.keys_per_memtable == 1
    rng = np.random.default_rng(5)
    n = 400
    ops = (rng.random(n) < 0.25).astype(np.uint8)
    keys = rng.integers(0, SCALE, n).astype(np.int64)
    arr = np.arange(n, dtype=np.float64) / 1e3
    _engine_parity(cfg, ops, keys, arr)


def test_lindley_zero_service():
    """Zero service: departures collapse to the running max of arrivals.
    Every kernel backend must match the numpy anchor on the degenerate
    queue (regression guard for the padded batch's -inf padding)."""
    from repro.kernels.lindley_scan.ops import lindley_batch_np, lindley_numpy
    n = 257                               # off the TILE boundary
    s = np.zeros(n, np.float64)
    rng = np.random.default_rng(9)
    a = np.sort(rng.random(n))
    a[n // 2:n // 2 + 8] = a[n // 2]      # plus a mid-queue burst
    anchor = lindley_numpy(s, a)
    np.testing.assert_array_equal(anchor, np.maximum.accumulate(a))
    for backend in ("numpy", "jnp", "pallas"):
        dep = lindley_batch_np([s], [a], backend=backend)[0]
        np.testing.assert_allclose(dep, anchor, rtol=0, atol=1e-12)


def test_lindley_burst_straddles_fill_event():
    """An arrival plateau centred on the keys_per_memtable-th write: the
    burst spans a window boundary, so the second window's wmax term comes
    from ops that queued BEFORE its fill event -- the case the per-window
    (wsum, wmax) aggregates must carry across windows."""
    cfg = LSMConfig.vlsm_default(scale=SCALE)
    kpm = cfg.keys_per_memtable
    assert kpm > 8
    n = 3 * kpm
    ops = np.zeros(n, np.uint8)           # all-PUT: windows every kpm ops
    rng = np.random.default_rng(13)
    keys = rng.integers(0, SCALE, n).astype(np.int64)
    arr = np.arange(n, dtype=np.float64) / 2e3
    arr[kpm - 4:kpm + 4] = arr[kpm - 4]   # burst straddling the boundary
    _engine_parity(cfg, ops, keys, arr)

import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device.  Multi-device tests spawn
# subprocesses (tests/test_multidevice.py).
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

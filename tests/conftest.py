import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device.  Multi-device tests spawn
# subprocesses (tests/test_multidevice.py).
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
# tests/ itself, for the hypothesis fallback shim (_propshim)
TESTS = Path(__file__).resolve().parent
if str(TESTS) not in sys.path:
    sys.path.insert(0, str(TESTS))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Continuous invariant validation: every LSMTree.drain_jobs() in the test
# suite runs the mechanism + policy invariant sweep (LSMConfig reads this
# env at construction; benchmarks leave it unset => off).
os.environ.setdefault("REPRO_PARANOID_CHECKS", "1")

"""Typed operation API (OpKind / RequestBatch / apply_batch) tests:

* SCAN correctness: ``scan_batch`` == a sorted slice of ``merged_view()``
  on stores grown through real flush/compaction histories;
* DELETE correctness: tombstoned keys read as not-found across memtable,
  flush, and compaction boundaries, and markers are reclaimed at the
  bottom level;
* ``apply_batch`` == the composed thin wrappers for mixed batches;
* scan/delete parity across the numpy / jnp / pallas LevelIndex backends;
* the exact-inverse-CDF ``pareto_keys`` regression (rank popularity must
  not depend on the sample size).
"""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propshim import HealthCheck, given, settings, st

from repro.bench_kv.workloads import make_run_e, pareto_keys
import repro.core.policies
from repro.core import (DeviceModel, LSMConfig, LSMTree, OpKind, RequestBatch,
                        Simulator)
from repro.core import level_index

CFG = LSMConfig.vlsm_default(scale=1 << 16)

# Every registered policy (including newly registered ones) is exercised.
POLICY_CFGS = tuple(
    repro.core.policies.default_configs(scale=1 << 16).values())


def _grow_tree(seed, n_ops=4000, cfg=CFG, delete_frac=0.15):
    """A store grown through the DES with interleaved PUTs and DELETEs, so
    tombstones cross flush and compaction boundaries."""
    rng = np.random.default_rng(seed)
    sim = Simulator(cfg, DeviceModel.scaled(1 / 1024))
    kinds = np.where(rng.random(n_ops) < delete_frac,
                     np.uint8(OpKind.DELETE), np.uint8(OpKind.PUT))
    keys = rng.integers(0, 900, size=n_ops).astype(np.int64)
    sim.run(kinds, keys, np.arange(n_ops, dtype=np.float64) / 1e4)
    return sim.trees[0], kinds, keys


# ----------------------------------------------------------------- scans
@given(st.integers(0, 2**32))
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_scan_batch_equals_merged_view_slice(seed):
    """Property: every scan returns exactly the sorted slice of the live
    merged view starting at its key, truncated to its length."""
    tree, _k, _ks = _grow_tree(seed)
    view = tree.merged_view()
    live_sorted = sorted(view)
    rng = np.random.default_rng(seed + 1)
    starts = np.concatenate([
        rng.integers(0, 900, size=24),        # in-range
        rng.integers(10**6, 10**9, size=4),   # past everything
        np.asarray([-5], np.int64),           # before everything
    ]).astype(np.int64)
    lens = rng.integers(1, 60, size=starts.shape[0]).astype(np.int32)
    res = tree.scan_batch(starts, lens)
    for i, (k, ln) in enumerate(zip(starts.tolist(), lens.tolist())):
        want = [x for x in live_sorted if x >= k][:ln]
        got_k, got_s = res.scan_slice(i)
        assert got_k.tolist() == want
        assert got_s.tolist() == [view[x] for x in want]
        assert int(res.seqs[i]) == len(want)


def test_scan_cost_accounting_sane():
    tree, _k, _ks = _grow_tree(3, n_ops=5000)
    res = tree.scan_batch(np.asarray([0], np.int64),
                          np.asarray([80], np.int32))
    assert int(res.seqs[0]) > 0
    assert int(res.probed[0]) >= 1          # at least one file seeked
    assert int(res.reads[0]) >= int(res.probed[0])  # >= one block per file
    # a scan past the keyspace touches nothing
    res = tree.scan_batch(np.asarray([10**15], np.int64),
                          np.asarray([10], np.int32))
    assert int(res.seqs[0]) == 0
    assert int(res.reads[0]) == 0 and int(res.probed[0]) == 0


# --------------------------------------------------------------- deletes
@given(st.integers(0, 2**32))
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_delete_then_get_not_found_across_boundaries(seed):
    """Property: after the full flush/compaction history, a GET agrees
    with the stream's last write per key — not-found iff it was a DELETE —
    for every policy's boundary behaviour."""
    tree, kinds, keys = _grow_tree(seed, n_ops=3000)
    last = {}
    for kind, key in zip(kinds.tolist(), keys.tolist()):
        last[key] = kind
    sample = np.asarray(list(last)[:300], np.int64)
    seqs, _r, _p = tree.get_batch(sample)
    for i, key in enumerate(sample.tolist()):
        if last[key] == OpKind.DELETE:
            assert int(seqs[i]) == -1, f"deleted key {key} resurfaced"
        else:
            assert int(seqs[i]) >= 0, f"live key {key} lost"


def test_delete_visible_through_memtable_flush_and_compaction():
    """DELETE-then-GET stays not-found when the tombstone sits in the
    memtable, then in an L0 SST, then below a compacted level."""
    cfg = CFG
    tree = LSMTree(cfg)
    room = tree.memtable.room
    keys = np.arange(room, dtype=np.int64)
    tree.put_batch(keys)
    tree.seal_memtable()
    tree.flush_immutable()
    # tombstone in the memtable
    tree.delete_batch(np.asarray([3], np.int64))
    assert tree.get(3)[0] is None
    # tombstone flushed to L0
    tree.seal_memtable()
    tree.flush_immutable()
    assert tree.get(3)[0] is None
    assert 3 not in tree.merged_view()
    # push more data through so compactions run; the key stays dead
    rng = np.random.default_rng(0)
    for _ in range(12):
        tree.put_batch(rng.integers(4, room, size=tree.memtable.room)
                       .astype(np.int64))
        tree.seal_memtable()
        tree.flush_immutable()
    tree.check_invariants()
    assert tree.get(3)[0] is None
    assert 3 not in tree.merged_view()


def test_tombstones_dropped_at_bottom_level():
    """Markers are reclaimed when a merge writes the bottom level and the
    Stats counters record the reclamation."""
    cfg = CFG.with_(max_levels=3)   # L0, L1, bottom L2
    tree = LSMTree(cfg)
    rng = np.random.default_rng(1)
    for i in range(24):
        n = tree.memtable.room
        keys = rng.integers(0, 400, size=n).astype(np.int64)
        if i % 2:
            tree.delete_batch(keys[: n // 2])
            tree.put_batch(keys[n // 2:])
        else:
            tree.put_batch(keys)
        tree.seal_memtable()
        tree.flush_immutable()
        tree.background_triggers()   # push L1 -> bottom
    assert tree.stats.delete_ops > 0
    assert tree.stats.tombstones_dropped > 0
    assert tree.stats.tombstone_bytes_dropped == \
        tree.stats.tombstones_dropped * cfg.kv_size
    # nothing at the bottom level carries a tombstone bit
    for sst in tree.levels[cfg.max_levels - 1]:
        assert not (np.asarray(sst.seqs) & 1).any()


# ----------------------------------------------------------- apply_batch
@given(st.integers(0, 2**32))
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_apply_batch_equals_composed_wrappers(seed):
    """Property: one mixed apply_batch == put_batch + delete_batch (stream
    order) then get_batch + scan_batch on two identically-grown stores."""
    import itertools

    import repro.core.lsm as lsm_mod
    import repro.core.sst as sst_mod
    rng = np.random.default_rng(seed)
    trees = []
    for _ in range(2):
        # identical uid sequences -> identical bloom false positives
        sst_mod._ids = itertools.count()
        lsm_mod._job_ids = itertools.count()
        tree, _k, _ks = _grow_tree(seed, n_ops=2500)
        if tree.memtable.n:               # start on an empty memtable
            tree.seal_memtable()
            tree.flush_immutable()
        trees.append(tree)
    tree_a, tree_b = trees

    # fixed composition (writes must fit the memtable), random order
    kinds = np.asarray([OpKind.PUT] * 18 + [OpKind.DELETE] * 12
                       + [OpKind.GET] * 25 + [OpKind.SCAN] * 25, np.uint8)
    rng.shuffle(kinds)
    n = kinds.shape[0]
    keys = rng.integers(0, 900, size=n).astype(np.int64)
    lens = np.where(kinds == OpKind.SCAN,
                    rng.integers(1, 40, size=n), 0).astype(np.int32)
    assert 30 <= tree_a.memtable.room

    res = tree_a.apply_batch(RequestBatch(kinds, keys, lens))

    # composed wrappers on tree_b: writes first (stream order, chunked at
    # each PUT/DELETE alternation), then reads
    w = (kinds == OpKind.PUT) | (kinds == OpKind.DELETE)
    widx = np.nonzero(w)[0]
    exp_seqs = np.full(n, -1, np.int64)
    seg_start = 0
    for j in range(1, widx.size + 1):
        if j == widx.size or kinds[widx[j]] != kinds[widx[seg_start]]:
            seg = widx[seg_start:j]
            fn = (tree_b.delete_batch
                  if kinds[seg[0]] == OpKind.DELETE else tree_b.put_batch)
            exp_seqs[seg] = fn(keys[seg])
            seg_start = j
    gidx = np.nonzero(kinds == OpKind.GET)[0]
    if gidx.size:
        s, r, p = tree_b.get_batch(keys[gidx])
        assert np.array_equal(res.seqs[gidx], s)
        assert np.array_equal(res.reads[gidx], r)
        assert np.array_equal(res.probed[gidx], p)
    sidx = np.nonzero(kinds == OpKind.SCAN)[0]
    if sidx.size:
        sres = tree_b.scan_batch(keys[sidx], lens[sidx])
        assert np.array_equal(res.seqs[sidx], sres.seqs)
        assert np.array_equal(res.reads[sidx], sres.reads)
        assert np.array_equal(res.probed[sidx], sres.probed)
        for j, i in enumerate(sidx.tolist()):
            ak, a_s = res.scan_slice(i)
            bk, b_s = sres.scan_slice(j)
            assert np.array_equal(ak, bk) and np.array_equal(a_s, b_s)
    if widx.size:
        assert np.array_equal(res.seqs[widx], exp_seqs[widx])
    # both trees end in identical user-visible state
    assert tree_a.merged_view() == tree_b.merged_view()


def test_wrappers_are_thin():
    """put/delete/get/scan wrappers return exactly what apply_batch does."""
    tree = LSMTree(CFG)
    keys = np.arange(20, dtype=np.int64)
    seqs = tree.put_batch(keys)
    assert seqs.tolist() == list(range(20))
    dseqs = tree.delete_batch(np.asarray([5, 6], np.int64))
    assert dseqs.tolist() == [20, 21]
    s, r, p = tree.get_batch(np.asarray([5, 7], np.int64))
    assert s.tolist() == [-1, 7]
    res = tree.scan_batch(np.asarray([4], np.int64),
                          np.asarray([3], np.int32))
    assert res.scan_slice(0)[0].tolist() == [4, 7, 8]  # 5, 6 deleted


def test_scalar_get_delegates_to_batch():
    tree, _k, _ks = _grow_tree(9, n_ops=2000)
    rng = np.random.default_rng(10)
    queries = np.concatenate([rng.integers(0, 900, size=100),
                              rng.integers(10**6, 10**9, size=30)]
                             ).astype(np.int64)
    b_seqs, b_reads, b_probed = tree.get_batch(queries)
    for i, k in enumerate(queries.tolist()):
        seq, reads, probed = tree.get(k)
        assert (seq if seq is not None else -1) == int(b_seqs[i])
        assert reads == int(b_reads[i])
        assert probed == int(b_probed[i])


# --------------------------------------------------------- backend parity
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_scan_delete_parity_across_index_backends(backend):
    """The jnp / pallas LevelIndex rank backends are drop-ins for the new
    scan + delete read paths (acceptance criterion)."""
    tree, _k, _ks = _grow_tree(21, n_ops=3000)
    rng = np.random.default_rng(22)
    starts = rng.integers(0, 900, size=40).astype(np.int64)
    lens = rng.integers(1, 50, size=40).astype(np.int32)
    gets = rng.integers(0, 900, size=100).astype(np.int64)
    ref_scan = tree.scan_batch(starts, lens)
    ref_get = tree.get_batch(gets)
    level_index.set_backend(backend)
    try:
        got_scan = tree.scan_batch(starts, lens)
        got_get = tree.get_batch(gets)
    finally:
        level_index.set_backend("numpy")
    for a, b in zip(ref_get, got_get):
        assert np.array_equal(a, b), f"{backend} GET path differs"
    for field in ("seqs", "reads", "probed", "scan_offsets", "scan_keys",
                  "scan_seqs"):
        assert np.array_equal(getattr(ref_scan, field),
                              getattr(got_scan, field)), \
            f"{backend} SCAN {field} differs"


@pytest.mark.parametrize("cfg", POLICY_CFGS, ids=lambda c: c.policy)
def test_delete_scan_all_policies(cfg):
    """The typed surface holds up under every compaction policy."""
    tree, kinds, keys = _grow_tree(33, n_ops=2500, cfg=cfg)
    tree.check_invariants()
    view = tree.merged_view()
    live_sorted = sorted(view)
    res = tree.scan_batch(np.asarray([0], np.int64),
                          np.asarray([100], np.int32))
    assert res.scan_slice(0)[0].tolist() == live_sorted[:100]
    last = {}
    for kind, key in zip(kinds.tolist(), keys.tolist()):
        last[key] = kind
    deleted = [k for k, v in last.items() if v == OpKind.DELETE][:50]
    s, _r, _p = tree.get_batch(np.asarray(deleted, np.int64))
    assert (s == -1).all()


# -------------------------------------------------------------- simulator
def test_sim_run_e_end_to_end():
    """YCSB-E drives the DES: scans get service, P99 is measurable, and
    scan accounting lands in Stats."""
    rng = np.random.default_rng(5)
    pop = np.unique(rng.integers(0, 2**40, 20_000).astype(np.int64))
    spec = make_run_e(pop, 10_000, dist="zipfian")
    cfg = LSMConfig.vlsm_default(scale=1 << 17)
    sim = Simulator(cfg, DeviceModel.scaled((1 << 17) / (64 << 20)))
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    lens = np.concatenate([np.zeros(pop.shape[0], np.int32),
                           spec.scan_lens])
    res = sim.run(op_types, keys,
                  np.arange(op_types.shape[0], dtype=np.float64) / 2e3,
                  scan_lens=lens)
    sc = res.op_types == OpKind.SCAN
    assert sc.sum() > 0
    assert res.p99_scan > 0.0
    assert "p99_scan_ms" in res.summary()
    assert sim.stats.scan_ops == int(sc.sum())
    assert sim.stats.scan_blocks > 0
    assert res.get_probed[sc].max() >= 1


def test_sim_deletes_through_des():
    """DELETE ops flow through the DES write path: they fill memtables,
    flush, and count as writes."""
    cfg = LSMConfig.vlsm_default(scale=1 << 16)
    sim = Simulator(cfg, DeviceModel.scaled(1 / 1024))
    rng = np.random.default_rng(6)
    n = 4000
    kinds = np.where(rng.random(n) < 0.3, np.uint8(OpKind.DELETE),
                     np.uint8(OpKind.PUT))
    keys = rng.integers(0, 600, size=n).astype(np.int64)
    res = sim.run(kinds, keys, np.arange(n, dtype=np.float64) / 1e4)
    assert sim.stats.delete_ops == int((kinds == OpKind.DELETE).sum())
    assert sim.stats.ops == n
    assert res.latency.shape[0] == n
    tree = sim.trees[0]
    tree.check_invariants()


# ------------------------------------------------------------ pareto fix
def test_pareto_keys_rank_popularity_independent_of_n():
    """Regression (seeded): the first draws are identical regardless of
    how many samples are requested — rank popularity is a fixed function
    of (rank, alpha, m), not of the sample size."""
    pop = np.sort(np.unique(
        np.random.default_rng(0).integers(0, 2**40, 5000))).astype(np.int64)
    short = pareto_keys(pop, 500, seed=13)
    long = pareto_keys(pop, 5000, seed=13)
    assert np.array_equal(short, long[:500])


def test_pareto_keys_pinned_values():
    """Seeded golden values for the exact inverse-CDF mapping."""
    pop = np.arange(100, dtype=np.int64)
    got = pareto_keys(pop, 8, alpha=1.16, seed=13)
    assert got.tolist() == [73, 73, 91, 22, 22, 66, 62, 22]


def test_pareto_keys_skewed_toward_head():
    """The head ranks carry most of the mass (Meta-like skew)."""
    pop = np.arange(10_000, dtype=np.int64)
    keys = pareto_keys(pop, 50_000, seed=13)
    perm = np.random.default_rng(14).permutation(10_000)
    ranks = np.empty(10_000, np.int64)
    ranks[perm] = np.arange(10_000)
    key_rank = ranks[keys]
    assert (key_rank < 100).mean() > 0.5   # top-1% ranks get >50% of hits


def test_run_e_shape():
    pop = np.arange(1000, dtype=np.int64) * 7
    spec = make_run_e(pop, 5000, dist="uniform")
    scans = spec.op_types == OpKind.SCAN
    frac = scans.mean()
    assert 0.93 < frac < 0.97
    assert (spec.scan_lens[scans] >= 1).all()
    assert (spec.scan_lens[scans] <= 100).all()
    assert (spec.scan_lens[~scans] == 0).all()
    # inserts are fresh keys, scan starts come from the population
    assert np.isin(spec.keys[scans], pop).all()

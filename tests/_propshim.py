"""Minimal, deterministic stand-in for ``hypothesis`` so the tier-1 suite
runs in environments without it (e.g. the hermetic bench container).

Install the real thing (``pip install -r requirements-dev.txt``) for actual
shrinking/coverage; this shim just replays ``max_examples`` seeded random
draws per test.  Only the strategy surface the test-suite uses is provided:
``st.integers`` and ``st.lists``.
"""

from __future__ import annotations

import zlib

import numpy as np


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)


st = strategies


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    # NOTE: no functools.wraps — pytest would inspect the wrapped signature
    # and try to inject the drawn arguments as fixtures.
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", None) \
                or getattr(fn, "_shim_max_examples", 20)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(*(s.draw(rng) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._shim_max_examples = getattr(fn, "_shim_max_examples", None)
        return wrapper
    return deco

"""Prefix cache, page pool, data pipeline determinism/resume, FT hooks."""

import numpy as np
import pytest

from repro.data import BatchAllocator, PipelineState, TokenPipeline
from repro.ft import FailureInjector, InjectedFailure, StepWatchdog
from repro.serving import PagePool, PrefixCache


def _pool(n=64):
    return PagePool(n_pages=n, page_size=16, n_layers=2, n_kv_heads=2,
                    head_dim=8)


def test_page_pool_refcounting():
    pool = _pool(4)
    pages = [pool.alloc() for _ in range(4)]
    with pytest.raises(MemoryError):
        pool.alloc()
    pool.pin(pages[0])
    pool.release(pages[0])
    assert pool.free_pages == 0      # still pinned once
    pool.release(pages[0])
    assert pool.free_pages == 1


def test_prefix_cache_match_and_evict():
    pool = _pool()
    pc = PrefixCache(pool, block_tokens=8)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, 32).astype(np.int32)
    pages = [[pool.alloc()] for _ in range(4)]
    assert pc.insert(toks, pages) == 4
    n, got_pages = pc.match(toks)
    assert n == 32 and len(got_pages) == 4
    # longest-prefix semantics: a diverging tail still matches the head
    toks2 = toks.copy(); toks2[20:] = 999
    n2, _ = pc.match(toks2)
    assert n2 == 16
    # unknown prompt: no match
    n3, _ = pc.match(rng.integers(1000, 2000, 32).astype(np.int32))
    assert n3 == 0
    free_before = pool.free_pages
    assert pc.evict_lru(2) == 2
    assert pool.free_pages >= free_before


def test_prefix_cache_under_churn_keeps_lsm_invariants():
    pool = PagePool(n_pages=4096, page_size=16, n_layers=1, n_kv_heads=1,
                    head_dim=4)
    pc = PrefixCache(pool, block_tokens=4)
    rng = np.random.default_rng(1)
    for i in range(300):
        toks = rng.integers(0, 10**6, 8).astype(np.int32)
        pc.insert(toks, [[pool.alloc()], [pool.alloc()]])
    pc.index.check_invariants()
    st = pc.index.stats
    assert st.user_bytes > 0


def test_pipeline_determinism_and_resume():
    st = PipelineState(seed=3, rank=0, world=2)
    p1 = TokenPipeline(1000, 16, 4, st)
    b1 = [p1.next_batch() for _ in range(5)]
    # resume from cursor 3 reproduces batches 3,4 exactly
    st2 = PipelineState(seed=3, rank=0, world=2, cursor=3)
    p2 = TokenPipeline(1000, 16, 4, st2)
    for i in range(3, 5):
        b = p2.next_batch()
        np.testing.assert_array_equal(b["tokens"], b1[i]["tokens"])
    # different rank -> different stream
    p3 = TokenPipeline(1000, 16, 4, PipelineState(seed=3, rank=1, world=2))
    assert not np.array_equal(p3.next_batch()["tokens"], b1[0]["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1[0]["labels"][:, :-1], b1[0]["tokens"][:, 1:])


def test_batch_allocator_work_stealing():
    alloc = BatchAllocator()
    a = [alloc.claim(0) for _ in range(3)]
    b = [alloc.claim(1) for _ in range(2)]
    assert sorted(a + b) == list(range(5))   # no batch lost or duplicated


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(threshold=3.0, alpha=0.5)
    for _ in range(3):
        wd.start(); time.sleep(0.002); wd.stop(0)
    wd.start(); time.sleep(0.05)
    assert wd.stop(3) is True
    assert wd.stragglers


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_step=2)
    inj.check(0); inj.check(1)
    with pytest.raises(InjectedFailure):
        inj.check(2)
    inj.check(2)  # idempotent after firing

"""Distribution layer: sharding-rule sanity, flash-decode combine math,
compression round-trips.  Multi-device behaviour runs in subprocesses
(test_multidevice.py) so this file keeps the 1-device default."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.compression import (compress_tree, dequantize_int8,
                                           quantize_int8)
from repro.distributed.flash_decode import (_local_partial,
                                            reference_decode_attn)
from repro.distributed.sharding import param_specs, zero1_specs
from repro.launch.specs import sanitize_spec


def test_param_specs_cover_tree():
    cfg = get_config("llama3_2_3b")
    import functools
    from repro.models import init_model
    shapes = jax.eval_shape(functools.partial(init_model, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(cfg, shapes)
    flat_sh = jax.tree.leaves(shapes)
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for sh, sp in zip(flat_sh, flat_sp):
        assert len(sp) <= len(sh.shape)


def test_zero1_folds_data_axis():
    cfg = get_config("llama3_2_3b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    import functools
    from repro.models import init_model
    shapes = jax.eval_shape(functools.partial(init_model, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    z = zero1_specs(cfg, shapes, FakeMesh())
    # embed [V, D] is vocab-sharded on model; zero1 folds data onto D
    assert z["embed"] == P("model", "data")
    del mesh


def test_sanitize_spec_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("model",))

    class M:
        axis_names = ("model",)
        shape = {"model": 16}
    assert sanitize_spec(M(), P("model", None), (64, 3)) == P("model", None)
    assert sanitize_spec(M(), P("model", None), (50280, 3)) == P(None, None)
    assert sanitize_spec(M(), P(("model",), None), (50280, 3)) == P(None, None)
    del mesh


def test_flash_decode_partial_combine_math():
    """Two half-cache partials combined with max-rescale == full attention."""
    rng = np.random.default_rng(0)
    b, h, dh, t = 2, 4, 16, 64
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
    pos = jnp.asarray([t - 1, 37], jnp.int32)
    full = reference_decode_attn(q, k, v, pos)

    scale = dh ** -0.5
    o1, l1, m1 = _local_partial(q, k[:, :32], v[:, :32], 0, pos, scale)
    o2, l2, m2 = _local_partial(q, k[:, 32:], v[:, 32:], 32, pos, scale)
    m = jnp.maximum(m1, m2)
    c1, c2 = jnp.exp(m1 - m), jnp.exp(m2 - m)
    l = l1 * c1 + l2 * c2
    o = (o1 * c1[..., None] + o2 * c2[..., None]) / jnp.maximum(
        l[..., None], 1e-30)
    got = o.reshape(b, h, dh)
    assert float(jnp.max(jnp.abs(got - full))) < 1e-5


def test_int8_quantize_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) + 1e-9


def test_error_feedback_preserves_sum():
    """Accumulated compressed gradients converge to the true sum (EF)."""
    rng = np.random.default_rng(2)
    true = jnp.asarray(rng.standard_normal(256), jnp.float32) * 1e-3
    opt_state = {}
    acc = jnp.zeros(256)
    for _ in range(50):
        g, opt_state = compress_tree({"g": true}, opt_state)
        acc = acc + g["g"]
    err = float(jnp.max(jnp.abs(acc / 50 - true)))
    assert err < 5e-4

"""Unit + property tests for the vLSM core (the paper's data structures)."""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # hermetic env: deterministic mini-shim
    from _propshim import HealthCheck, given, settings, st

from repro.core import LSMConfig, LSMTree, Policy, Simulator, DeviceModel
from repro.core import merge as merge_backend
from repro.core.memtable import Memtable
from repro.core.sst import SST, overlapping, split_fixed
from repro.core.vsst import (l2_fences, overlap_count_range, plan_vssts,
                             select_good_vssts)

CFG = LSMConfig.vlsm_default(scale=1 << 16)  # tiny: fast trees in tests


# --------------------------------------------------------------- memtable
def test_memtable_latest_wins():
    mt = Memtable(capacity_bytes=10_000, kv_size=100)
    mt.put_batch(np.array([5, 3, 5]), np.array([1, 2, 3]))
    keys, seqs = mt.to_sorted()
    assert keys.tolist() == [3, 5]
    assert seqs.tolist() == [2, 3]
    assert mt.get(5) == 3
    assert mt.get(99) is None


# -------------------------------------------------------------------- SST
def test_overlapping_selection():
    ssts = [SST(np.arange(i * 10, i * 10 + 10, dtype=np.int64),
                np.zeros(10, np.int64), 100) for i in range(5)]
    got = overlapping(ssts, 12, 33)
    assert [s.smallest for s in got] == [10, 20, 30]
    assert overlapping(ssts, 200, 300) == []
    assert [s.smallest for s in overlapping(ssts, -5, 0)] == [0]


def test_split_fixed_sizes():
    keys = np.arange(1000, dtype=np.int64)
    out = split_fixed(keys, keys.copy(), kv_size=100, sst_size=10_000)
    assert all(s.size <= 10_000 for s in out)
    assert sum(s.n for s in out) == 1000


# ------------------------------------------------------------------ merge
@given(st.lists(st.integers(0, 2**40), min_size=0, max_size=300),
       st.lists(st.integers(0, 2**40), min_size=0, max_size=300))
@settings(max_examples=30, deadline=None)
def test_merge_numpy_latest_wins(a, b):
    a = np.unique(np.asarray(a, np.int64))
    b = np.unique(np.asarray(b, np.int64))
    runs = [(b, np.arange(1000, 1000 + b.size)),   # newer
            (a, np.arange(a.size))]                 # older
    keys, seqs = merge_backend.merge_runs(runs)
    assert np.all(np.diff(keys) > 0)
    ref = {}
    for k, s in zip(a.tolist(), range(a.size)):
        ref[k] = s
    for k, s in zip(b.tolist(), range(1000, 1000 + b.size)):
        ref[k] = s
    assert dict(zip(keys.tolist(), seqs.tolist())) == ref


# ---------------------------------------------------------------- vSSTs
def _mk_l2(n_ssts, keys_per, kv=100, spacing=1000):
    out = []
    for i in range(n_ssts):
        ks = np.arange(i * spacing, i * spacing + keys_per, dtype=np.int64)
        out.append(SST(ks, np.zeros(keys_per, np.int64), kv))
    return out


def test_overlap_count():
    l2 = _mk_l2(10, 100)
    lo, hi = l2_fences(l2)
    assert overlap_count_range(lo, hi, 0, 50) == 1
    assert overlap_count_range(lo, hi, 0, 1000) == 2
    assert overlap_count_range(lo, hi, 150, 150) == 0   # in a gap
    assert overlap_count_range(lo, hi, -10, 10**9) == 10


@given(st.integers(2, 40), st.integers(0, 2**20))
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_plan_vssts_properties(n_l2, seed):
    """Plans must tile the stream exactly; sizes within [S_m, S_M] except a
    possibly-bigger merged tail; good plans have overlap <= f."""
    rng = np.random.default_rng(seed)
    kv, f = 100, 4
    s_M, s_m = 40 * kv, 10 * kv
    l2 = _mk_l2(n_l2, 50, kv=kv, spacing=5000)
    lo, hi = l2_fences(l2)
    keys = np.unique(rng.integers(0, n_l2 * 5000, size=600).astype(np.int64))
    plans = plan_vssts(keys, kv, s_m, s_M, f, lo, hi, sst_size_l2=50 * kv)
    assert plans[0].start == 0 and plans[-1].end == keys.size
    for a, b in zip(plans, plans[1:]):
        assert a.end == b.start
    for p in plans:
        n = p.end - p.start
        assert n * kv <= s_M + s_m + kv   # S_M + tail-absorption slack
        got = overlap_count_range(lo, hi, int(keys[p.start]),
                                  int(keys[p.end - 1]))
        assert got == p.overlap_ssts
        if p.good:
            assert p.overlap_ssts <= f


@given(st.integers(1, 40), st.integers(0, 2**20), st.integers(1, 6),
       st.integers(2, 60))
@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_plan_vssts_matches_ref(n_l2, seed, f, max_kv):
    """The closed-form planner is plan-for-plan identical to the segment
    walk across fence densities, growth factors and size windows."""
    from repro.core.vsst import plan_vssts_ref
    rng = np.random.default_rng(seed)
    kv = 100
    s_M, s_m = max_kv * kv, max(1, max_kv // 4) * kv
    l2 = _mk_l2(n_l2, 50, kv=kv, spacing=int(rng.integers(100, 5000)))
    lo, hi = l2_fences(l2)
    keys = np.unique(rng.integers(-500, n_l2 * 5000,
                                  size=int(rng.integers(1, 500))
                                  ).astype(np.int64))
    args = (keys, kv, s_m, s_M, f, lo, hi, 50 * kv)
    assert plan_vssts(*args) == plan_vssts_ref(*args)
    # empty-fence degenerate case
    z = np.empty(0, np.int64)
    args = (keys, kv, s_m, s_M, f, z, z, 50 * kv)
    assert plan_vssts(*args) == plan_vssts_ref(*args)


def test_select_good_prefers_low_ratio():
    kv, f = 100, 4
    l2 = _mk_l2(8, 50, kv=kv, spacing=5000)
    lo, hi = l2_fences(l2)
    # one vSST inside a single L2 SST (good, low ratio), one spanning many
    good = SST(np.arange(0, 40, dtype=np.int64), np.zeros(40, np.int64), kv)
    poor = SST(np.arange(100, 40_000, 800, dtype=np.int64),
               np.zeros(50, np.int64), kv)
    picked = select_good_vssts([poor, good], lo, hi, 50 * kv, f,
                               bytes_needed=1)
    assert picked == [1]


# ------------------------------------------------------------- tree props
@given(st.integers(0, 2**32), st.integers(200, 3000))
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_tree_get_after_put_latest_wins(seed, n_ops):
    rng = np.random.default_rng(seed)
    for policy_cfg in (CFG, LSMConfig.rocksdb_default(scale=1 << 16)):
        sim = Simulator(policy_cfg, DeviceModel.scaled(1 / 1024))
        keys = rng.integers(0, 500, size=n_ops).astype(np.int64)  # duplicates!
        ops = np.zeros(n_ops, np.uint8)
        arr = np.arange(n_ops) / 1e4
        sim.run(ops, keys, arr)
        tree = sim.trees[0]
        tree.check_invariants()
        view = tree.merged_view()
        # latest-wins: last occurrence of key in stream has highest seq
        last_seq = {}
        for i, k in enumerate(keys.tolist()):
            last_seq[k] = i
        assert view == last_seq
        # point lookups agree with the merged view on a sample
        for k in list(view)[:50]:
            got, _r, _p = tree.get(k)
            assert got == view[k]
        missing, _r, _p = tree.get(10**15)
        assert missing is None


def test_vlsm_level_structure():
    sim = Simulator(CFG, DeviceModel.scaled(1 / 1024))
    rng = np.random.default_rng(0)
    n = 5000
    sim.run(np.zeros(n, np.uint8),
            rng.integers(0, 2**40, n).astype(np.int64),
            np.arange(n) / 1e4)
    tree = sim.trees[0]
    tree.check_invariants()
    st_ = sim.stats
    assert st_.vssts_good + st_.vssts_poor > 0
    # the paper's Φ=32 regime: most vSSTs are good (Fig 13b shows ~90%)
    frac_good = st_.vssts_good / (st_.vssts_good + st_.vssts_poor)
    assert frac_good > 0.5
    # L0 never exceeds the stop limit structurally
    assert len(tree.levels[0]) <= CFG.l0_stop_ssts


def test_merge_backends_agree():
    rng = np.random.default_rng(3)
    a = np.unique(rng.integers(0, 2**40, 400).astype(np.int64))
    b = np.unique(rng.integers(0, 2**40, 300).astype(np.int64))
    runs = [(b, np.arange(500, 500 + b.size)), (a, np.arange(a.size))]
    merge_backend.set_backend("numpy")
    k1, s1 = merge_backend.merge_runs(runs)
    merge_backend.set_backend("jnp")
    k2, s2 = merge_backend.merge_runs(runs)
    merge_backend.set_backend("pallas")
    k3, s3 = merge_backend.merge_runs(runs)
    merge_backend.set_backend("numpy")
    assert np.array_equal(k1, k2) and np.array_equal(s1, s2)
    assert np.array_equal(k1, k3) and np.array_equal(s1, s3)

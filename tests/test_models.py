"""Per-arch smoke tests (reduced configs, CPU): one forward/train step,
shape + finiteness asserts, prefill->decode parity, MoE/MLA specifics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_cache, init_model, train_loss

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, s=S):
    tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.random.normal(
            KEY, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = forward(cfg, params, batch, mode="train", remat=True)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = train_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: train_loss(cfg, p, batch, remat=True))(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, KEY)
    batch = _batch(cfg)
    logits, cache = forward(cfg, params, batch, mode="prefill",
                            cache_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    for i in range(2):
        lg, cache = decode_step(cfg, params, tok, pos + i, cache)
        assert lg.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(lg)))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma3_1b", "qwen3_1_7b",
                                  "deepseek_v2_lite", "mamba2_130m",
                                  "zamba2_1_2b", "whisper_tiny"])
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, KEY)
    batch = _batch(cfg, s=S)
    full, _ = forward(cfg, params, batch, mode="train", remat=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    pre.pop("labels")
    _, cache = forward(cfg, params, pre, mode="prefill", cache_len=S + 4)
    pos = jnp.full((B,), S - 1, jnp.int32)
    lg, _ = decode_step(cfg, params, batch["tokens"][:, S - 1:S], pos, cache)
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, S - 1])))
    assert err < 2e-3, err


def test_mla_absorbed_equals_expanded():
    cfg = get_config("deepseek_v2_lite").smoke()
    params = init_model(cfg, KEY)
    batch = _batch(cfg)
    batch.pop("labels")
    _, cache = forward(cfg, params, batch, mode="prefill", cache_len=S + 4)
    pos = jnp.full((B,), S, jnp.int32)
    tok = batch["tokens"][:, :1]
    a, _ = decode_step(cfg, params, tok, pos, cache, absorbed_mla=True)
    b, _ = decode_step(cfg, params, tok, pos, cache, absorbed_mla=False)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-3


def test_moe_routes_to_multiple_experts():
    from repro.models.moe import init_moe, moe_forward
    cfg = get_config("deepseek_v2_lite").smoke()
    p = init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_forward(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) > 0
    # shared experts contribute even when routing collapses
    out2, _ = moe_forward(cfg, {**p, "router": p["router"] * 0}, x)
    assert bool(jnp.all(jnp.isfinite(out2)))


def test_gemma_window_pattern():
    cfg = get_config("gemma3_1b")
    ws = cfg.layer_windows()
    assert len(ws) == 26
    assert ws[5] == -1 and ws[11] == -1      # every 6th global
    assert ws[0] == 512 and ws[4] == 512
    assert sum(1 for w in ws if w == -1) == 4


def test_ssd_decode_matches_forward():
    """SSM per-step decode equals the full-sequence scan."""
    cfg = get_config("mamba2_130m").smoke()
    params = init_model(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, 12), 0, cfg.vocab_size)
    full, _ = forward(cfg, params, {"tokens": tokens}, mode="train",
                      remat=False)
    cache = init_cache(cfg, B, 16)
    for t in range(12):
        lg, cache = decode_step(cfg, params, tokens[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32), cache)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 2e-3, (t, err)

"""Sharding layer: routing partition, gather order, single-shard parity.

The contract pinned here:

* ``ShardRouter`` is a *partition*: every key maps to exactly one shard
  in ``[0, n_shards)``, deterministically, for both router kinds;
* ``ShardedStore(n_shards=1)`` is **byte-identical** to a bare
  ``LSMTree`` driven with the same seal-on-full cadence — merged_view,
  GET accounting (seqs/reads/probed), SCAN payloads, and the chain
  ledger all match, for every registered policy;
* re-gather preserves arrival order: results land at their op's
  position regardless of how sub-batches interleaved across shards;
* multi-shard semantics: the union of the shards is the store (same
  live keys / scan windows as a single tree), and one hot shard's
  background work inflates the other shard's foreground reads through
  the shared device (the cross-shard interference mechanism).
"""

import itertools

import numpy as np
import pytest

import repro.core.lsm as lsm_mod
import repro.core.sst as sst_mod
from repro.core import (DeviceModel, FleetStats, LSMConfig, LSMTree, OpKind,
                        RequestBatch, ShardRouter, ShardedStore, Simulator,
                        get_policy, policies)

SCALE = 1 << 17
LAM = SCALE / (64 << 20)


def _reset_counters():
    """Fresh process-global uid counters: bloom FP hashing mixes sst.uid
    and the ledger compares job/chain uids across runs."""
    sst_mod._ids = itertools.count()
    lsm_mod._job_ids = itertools.count()
    lsm_mod._chain_ids = itertools.count()


# ----------------------------------------------------------------- router
@pytest.mark.parametrize("kind", ["hash", "range"])
@pytest.mark.parametrize("n_shards", [1, 2, 4, 7])
def test_router_is_a_partition(kind, n_shards):
    r = ShardRouter(n_shards, kind)
    keys = np.random.default_rng(3).integers(0, 1 << 48, 20_000,
                                             dtype=np.int64)
    s = r.shard_of(keys)
    assert s.shape == keys.shape
    assert (s >= 0).all() and (s < n_shards).all()
    # deterministic (same keys -> same shards), and a FUNCTION of the key:
    # duplicated keys route identically
    assert (r.shard_of(keys) == s).all()
    dup = np.concatenate([keys, keys])
    sd = r.shard_of(dup)
    assert (sd[:20_000] == sd[20_000:]).all()
    if n_shards > 1:
        # every shard actually receives load on a uniform keyspace
        assert np.unique(s).shape[0] == n_shards


def test_range_router_stripes_contiguously():
    r = ShardRouter(4, "range", key_space=1 << 20)
    keys = np.arange(0, 1 << 20, 997, dtype=np.int64)
    s = r.shard_of(keys)
    # non-decreasing along the key order — contiguous stripes
    assert (np.diff(s) >= 0).all()
    assert s[0] == 0 and s[-1] == 3


def test_hash_router_scatters_ranges():
    r = ShardRouter(4, "hash")
    s = r.shard_of(np.arange(1000, dtype=np.int64))
    # a contiguous key range spreads over every shard
    counts = np.bincount(s, minlength=4)
    assert (counts > 100).all()


# ------------------------------------------------- single-shard parity
def _drive_tree(cfg: LSMConfig, ops):
    """Reference driver: a bare LSMTree fed the same op stream with the
    seal-on-full cadence ShardedStore uses (chunk at memtable room; a
    full memtable rolls through flush + background triggers)."""
    tree = LSMTree(cfg)
    results = []
    for kind, payload in ops:
        if kind == "write":
            keys, tombs = payload
            i, n = 0, keys.shape[0]
            while i < n:
                if tree.memtable.room == 0:
                    tree.seal_memtable()
                    tree.flush_immutable()
                    tree.background_triggers()
                    tree.drain_jobs()
                take = min(tree.memtable.room, n - i)
                tree._write_batch(keys[i:i + take], tombs[i:i + take])
                i += take
                if tree.memtable.full:
                    tree.seal_memtable()
                    tree.flush_immutable()
                    tree.background_triggers()
                    tree.drain_jobs()
        elif kind == "get":
            results.append(tree.apply_batch(RequestBatch.gets(payload)))
        else:
            starts, lens = payload
            results.append(tree.apply_batch(RequestBatch.scans(starts, lens)))
    return tree, results


def _drive_store(cfg: LSMConfig, ops):
    store = ShardedStore(cfg)
    results = []
    for kind, payload in ops:
        if kind == "write":
            keys, tombs = payload
            kinds = np.where(tombs, np.uint8(OpKind.DELETE),
                             np.uint8(OpKind.PUT))
            store.apply_batch(RequestBatch(kinds, keys))
        elif kind == "get":
            results.append(store.apply_batch(RequestBatch.gets(payload)))
        else:
            starts, lens = payload
            results.append(store.apply_batch(
                RequestBatch.scans(starts, lens)))
    return store, results


def _mixed_ops(seed=5, n_writes=6_000):
    r = np.random.default_rng(seed)
    pool = r.integers(0, 1 << 40, n_writes, dtype=np.int64)
    ops = []
    for lo in range(0, n_writes, 1_000):
        chunk = pool[lo:lo + 1_000]
        tombs = r.random(chunk.shape[0]) < 0.05
        ops.append(("write", (chunk, tombs)))
        ops.append(("get", r.choice(pool[:lo + 1_000], 300)))
        starts = r.choice(pool[:lo + 1_000], 5)
        lens = r.integers(1, 40, 5).astype(np.int32)
        ops.append(("scan", (starts, lens)))
    return ops


@pytest.mark.parametrize("pname", policies.names())
def test_single_shard_store_byte_identical_to_tree(pname):
    """ShardedStore(n_shards=1) == bare LSMTree: merged_view, GET
    accounting, SCAN payloads, chain ledger — per registered policy."""
    cfg = get_policy(pname).default_config(scale=SCALE)
    ops = _mixed_ops()
    _reset_counters()
    tree, t_res = _drive_tree(cfg, ops)
    _reset_counters()
    store, s_res = _drive_store(cfg.with_(n_shards=1), ops)

    assert store.merged_view() == tree.merged_view()
    assert len(s_res) == len(t_res)
    for tr, sr in zip(t_res, s_res):
        np.testing.assert_array_equal(sr.seqs, tr.seqs)
        np.testing.assert_array_equal(sr.reads, tr.reads)
        np.testing.assert_array_equal(sr.probed, tr.probed)
        np.testing.assert_array_equal(sr.scan_offsets, tr.scan_offsets)
        np.testing.assert_array_equal(sr.scan_keys, tr.scan_keys)
        np.testing.assert_array_equal(sr.scan_seqs, tr.scan_seqs)
    # the chain ledger replays identically (ids, shape, job uids)
    t_chains = tree.stats.chains
    s_chains = store.stats.chains
    assert len(s_chains) == len(t_chains)
    for tc, sc in zip(t_chains, s_chains):
        assert (sc.chain_id, sc.trigger, sc.length, sc.width,
                sc.width_bytes, sc.n_jobs, sc.job_uids) == \
               (tc.chain_id, tc.trigger, tc.length, tc.width,
                tc.width_bytes, tc.n_jobs, tc.job_uids)


# --------------------------------------------------- multi-shard routing
def test_store_partition_and_gather_order():
    """Every key lives in exactly one shard; results re-gather at their
    arrival positions regardless of shard interleaving."""
    cfg = LSMConfig.vlsm_default(scale=SCALE).with_(n_shards=4)
    store = ShardedStore(cfg)
    r = np.random.default_rng(11)
    keys = np.unique(r.integers(0, 1 << 40, 5_000, dtype=np.int64))
    store.put_batch(keys)
    views = [t.merged_view() for t in store.shards]
    sizes = [len(v) for v in views]
    # partition: the shard views are disjoint and their union is the store
    assert sum(sizes) == keys.shape[0]
    union = set()
    for v in views:
        assert not (union & v.keys())
        union |= v.keys()
    assert union == set(keys.tolist())
    # routing agreement: each key sits in the shard the router names
    sid = store.shard_of(keys)
    for s in range(4):
        assert set(keys[sid == s].tolist()) == set(views[s].keys())
    # gather order: shuffled GETs answer at their own positions
    probe = r.permutation(keys)[:1_000]
    seqs, _reads, _probed = store.get_batch(probe)
    expect = store.merged_view()
    assert [expect[int(k)] for k in probe.tolist()] == seqs.tolist()


def test_multi_shard_semantics_match_single_tree():
    """Liveness and scan windows are shard-count-invariant (seqnos are
    per-shard, so compare user-visible keys, not seq values)."""
    r = np.random.default_rng(13)
    keys = np.unique(r.integers(0, 1 << 40, 4_000, dtype=np.int64))
    dead = keys[r.random(keys.shape[0]) < 0.1]
    cfg1 = LSMConfig.vlsm_default(scale=SCALE)
    stores = []
    for n in (1, 4):
        st = ShardedStore(cfg1.with_(n_shards=n))
        st.put_batch(keys)
        if dead.size:
            st.delete_batch(dead)
        stores.append(st)
    v1, v4 = (set(s.merged_view().keys()) for s in stores)
    assert v1 == v4
    starts = r.choice(keys, 8)
    lens = np.full(8, 25, np.int32)
    r1 = stores[0].scan_batch(starts, lens)
    r4 = stores[1].scan_batch(starts, lens)
    np.testing.assert_array_equal(r1.scan_keys, r4.scan_keys)
    np.testing.assert_array_equal(r1.scan_offsets, r4.scan_offsets)


# ------------------------------------------------------------- DES level
def test_sim_shards_partition_ops_and_stats():
    cfg = LSMConfig.vlsm_default(scale=SCALE).with_(n_shards=3)
    sim = Simulator(cfg, DeviceModel.scaled(LAM))
    n = 30_000
    keys = np.random.default_rng(7).integers(0, 1 << 44, n, dtype=np.int64)
    res = sim.run(np.zeros(n, np.uint8), keys, np.arange(n) / 5e3)
    assert res.shard_ids is not None
    np.testing.assert_array_equal(res.shard_ids,
                                  sim.router.shard_of(keys))
    rows = res.per_shard_summary()
    assert len(rows) == 3 and sum(r["ops"] for r in rows) == n
    # per-shard ledgers: fleet counters are the shard sums
    assert isinstance(res.stats, FleetStats)
    assert res.stats.user_bytes == sum(st.user_bytes
                                       for st in sim.shard_stats)
    assert res.stats.flush_bytes == sum(st.flush_bytes
                                        for st in sim.shard_stats)
    # every job is stamped with the shard whose tree emitted it
    shards_seen = {j.shard for j in sim.job_log}
    assert shards_seen == {0, 1, 2}
    for j in sim.job_log:
        assert j.chain_id in sim.shard_stats[j.shard].chain_index or \
            j.kind == "flush"
    # the fleet chain report carries the per-shard breakdown
    rep = res.chain_report()
    assert len(rep["per_shard"]) == 3
    assert sum(p["n_chains"] for p in rep["per_shard"]) == rep["n_chains"]


def test_fleet_stats_read_only():
    fs = FleetStats([lsm_mod.Stats(), lsm_mod.Stats()])
    with pytest.raises(AttributeError):
        fs.user_bytes = 7


def test_hot_shard_inflates_cold_shard_reads():
    """Cross-shard interference: a write-hot shard's compactions run on
    the SHARED device, so the cold shard's GETs get slower even though
    its own tree is idle — the tail-interference mechanism shard_sweep
    measures."""
    cfg = LSMConfig.vlsm_default(scale=SCALE).with_(
        n_shards=2, shard_router="range", shard_key_space=1 << 40)
    r = np.random.default_rng(23)
    half = 1 << 39
    cold_keys = np.unique(r.integers(half, 1 << 40, 4_000, dtype=np.int64))
    hot_keys = r.integers(0, half, 40_000, dtype=np.int64)
    probe = r.choice(cold_keys, 4_000)

    def run(with_hot: bool):
        sim = Simulator(cfg, DeviceModel.scaled(LAM))
        # preload the cold shard, then measured GETs against it at a
        # fixed rate, with (or without) a concurrent write flood to the
        # hot shard
        ops = [np.zeros(cold_keys.shape[0], np.uint8),
               np.ones(probe.shape[0], np.uint8)]
        key_arr = [cold_keys, probe]
        arr = [np.arange(cold_keys.shape[0]) / 1e6]
        t0 = arr[0][-1] + 1.0
        arr.append(t0 + np.arange(probe.shape[0]) / 2e3)
        if with_hot:
            ops.append(np.zeros(hot_keys.shape[0], np.uint8))
            key_arr.append(hot_keys)
            arr.append(t0 + np.arange(hot_keys.shape[0]) / 20e3)
        op_types = np.concatenate(ops)
        keys = np.concatenate(key_arr)
        arrivals = np.concatenate(arr)
        order = np.argsort(arrivals, kind="stable")
        res = sim.run(op_types[order], keys[order], arrivals[order])
        gets = res.op_types == OpKind.GET
        return float(np.percentile(res.latency[gets], 99))

    assert run(True) > run(False)


# -------------------------------------------------------- satellite: CLI
def test_db_bench_unknown_names_exit_cleanly(capsys):
    """Unknown --policy / --bench names exit via argparse with the
    registered list, not a KeyError traceback."""
    from repro.bench_kv.db_bench import main
    with pytest.raises(SystemExit) as e:
        main(["--policy", "nope", "--json", ""])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "registered" in err and "vlsm" in err
    with pytest.raises(SystemExit) as e:
        main(["--bench", "nope", "--json", ""])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "available" in err and "shard_sweep" in err


def test_summary_has_p999_fields():
    cfg = LSMConfig.vlsm_default(scale=SCALE)
    sim = Simulator(cfg, DeviceModel.scaled(LAM))
    n = 5_000
    keys = np.random.default_rng(3).integers(0, 1 << 40, n, dtype=np.int64)
    out = sim.run(np.zeros(n, np.uint8), keys, np.arange(n) / 2e3).summary()
    for k in ("p999_ms", "p999_put_ms", "p999_get_ms"):
        assert k in out

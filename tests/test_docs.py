"""Docs integrity: the acceptance-gated docs tree exists and the offline
markdown link check CI runs (scripts/check_links.py) passes in-tree."""

import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_required_docs_exist():
    for name in ("architecture.md", "paper_map.md", "benchmarks.md"):
        assert (ROOT / "docs" / name).is_file(), f"docs/{name} missing"


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_links.py"),
         "README.md", "docs"],
        capture_output=True, text=True, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_link_checker_catches_breakage(tmp_path):
    (tmp_path / "bad.md").write_text("see [x](no_such_file.md) "
                                     "and [y](#no-such-heading)\n# Real\n")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_links.py"),
         str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "no_such_file.md" in proc.stdout
    assert "no-such-heading" in proc.stdout

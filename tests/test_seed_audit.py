"""Seed-threading audit over every registered bench family.

``db_bench.main --seed S`` must thread S through every workload
generator and arrival process it drives: running the same family twice
with the same seed yields byte-identical JSON rows (modulo wall-clock
fields), and a different seed yields different latency samples.  A
family that silently ignores ``--seed`` (a hardcoded generator seed, an
unseeded RNG) fails the first or second assertion respectively.

The audit is parametrized over ``db_bench.BENCHES`` so a newly
registered family is audited automatically — forgetting to thread the
seed through a new bench is a test failure, not a silent drift.
"""

import json

import pytest

from repro.bench_kv import db_bench
from repro.core import reset_uid_counters

# wall-clock-derived fields: genuinely nondeterministic, excluded from
# the byte-compare (everything else must reproduce).  The executor's
# phase timings and cache/ledger counters join them: a repeated
# in-process run may HIT the structural cache (bit-identical results,
# but cache_hit flips and structural_s collapses to 0.0).
VOLATILE = {"wall_clock_s", "fleet_wall_s", "serial_wall_s", "speedup",
            "structural_s", "temporal_s", "lindley_s", "finalize_s",
            "cache_hit", "executor_wall_s", "serial_equiv_s",
            "cache_hits", "cache_misses", "tasks", "workers"}


def _strip(row: dict) -> dict:
    return {k: v for k, v in row.items() if k not in VOLATILE}


def _run(bench: str, seed: int, tmp_path, tag: str,
         workers: int = 1) -> list[dict]:
    out = tmp_path / f"{bench}_{tag}.json"
    # uid counters seed the bloom filters; rewind so repeated in-process
    # runs start from the fresh-interpreter state the CLI sees
    reset_uid_counters()
    db_bench.main(["--bench", bench, "--quick", "--policy", "vlsm",
                   "--seed", str(seed), "--json", str(out),
                   "--workers", str(workers)])
    return [_strip(r) for r in json.loads(out.read_text())]


@pytest.mark.parametrize("bench", db_bench.BENCHES)
def test_seed_threads_through_family(bench, tmp_path, monkeypatch, capsys):
    # shrink the sweep axes: the audit checks seed plumbing, not curves
    monkeypatch.setattr(db_bench, "FLEET_RATES_QUICK", (2_000.0, 6_000.0))
    monkeypatch.setattr(db_bench, "SHARD_COUNTS", (1, 2))
    monkeypatch.setattr(db_bench, "SERVE_FACTORS_QUICK", (1.0, 3.0))

    base = _run(bench, 7, tmp_path, "a")
    again = _run(bench, 7, tmp_path, "b")
    other = _run(bench, 13, tmp_path, "c")
    capsys.readouterr()                      # swallow the bench prints

    assert base, f"{bench} emitted no rows"
    assert base == again, \
        f"{bench}: same seed must reproduce identical rows"
    assert base != other, \
        f"{bench}: --seed is not threaded through (rows identical " \
        f"across seeds)"


@pytest.mark.parametrize("bench", ("fleet_sweep", "serve_sweep"))
def test_executor_workers_row_parity(bench, tmp_path, monkeypatch, capsys):
    """Executor-driven families: the fork pool must not perturb a single
    row — workers=2 reproduces the workers=1 rows byte-identically
    (modulo the volatile timing fields)."""
    monkeypatch.setattr(db_bench, "FLEET_RATES_QUICK", (2_000.0, 6_000.0))
    monkeypatch.setattr(db_bench, "SERVE_FACTORS_QUICK", (1.0, 3.0))

    serial = _run(bench, 7, tmp_path, "w1", workers=1)
    pooled = _run(bench, 7, tmp_path, "w2", workers=2)
    capsys.readouterr()

    assert serial, f"{bench} emitted no rows"
    assert serial == pooled, \
        f"{bench}: workers=2 rows diverge from workers=1"

"""Multi-device behaviour (shard_map pipeline, seq-sharded flash decode,
dry-run micro-cell) in subprocesses with forced host devices — the main
test process keeps 1 device."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_matches_reference():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply, unpipelined_reference
        mesh = jax.make_mesh((4,), ("pipe",))
        S, M, mb, d = 4, 6, 2, 16
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.standard_normal((S, d, d)) * 0.3, jnp.float32)}
        x = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)
        stage = lambda p, h: jnp.tanh(h @ p["w"])
        got = pipeline_apply(mesh, stage, params, x, n_micro=M)
        ref = unpipelined_reference(stage, params, x)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-5, err
        print("pipeline ok", err)
    """)


def test_seq_sharded_flash_decode():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.flash_decode import (seq_sharded_decode_attn,
                                                    reference_decode_attn)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        b, h, dh, t = 2, 4, 16, 64
        q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
        pos = jnp.asarray([t - 1, 29], jnp.int32)
        got = seq_sharded_decode_attn(mesh, q, k, v, pos)
        ref = reference_decode_attn(q, k, v, pos)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-5, err
        print("flash decode ok", err)
    """)


def test_compressed_psum_wire_and_value():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        mesh = jax.make_mesh((4,), ("pod",))
        x = jnp.ones((128, 128), jnp.float32) * 0.5

        def body(x):
            return compressed_psum(x, "pod")
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),),
                              out_specs=P(), check_rep=False))
        got = f(x)
        assert abs(float(got[0, 0]) - 0.5) < 0.02, float(got[0, 0])
        # int8 payload on the wire: the all-reduce operates on s32 <= 4B,
        # and the quantized operand is s8
        txt = f.lower(x).compile().as_text()
        assert "s32[" in txt or "s8[" in txt
        print("compressed psum ok")
    """)


def test_dryrun_microcell_multipod():
    """A tiny end-to-end multi-pod lower+compile (2x2x2 mesh) proving the
    'pod' axis shards — the 512-dev variant runs via scripts/run_dryruns.

    ``Compiled.cost_analysis()`` drifted across jax versions: older
    releases return ``[{...}]`` (one dict per computation), newer ones the
    dict itself — normalize before reading flops."""
    _run("""
        import jax, jax.numpy as jnp, functools
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_model, train_loss
        from repro.launch.specs import train_specs
        from repro.configs.base import ShapeSpec
        cfg = get_config("qwen3_1_7b").smoke()
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeSpec("t", 32, 8, "train")
        params, opt, batch = train_specs(cfg, shape, mesh)
        def step(p, b):
            return train_loss(cfg, p, b)
        lowered = jax.jit(step).lower(params, batch)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # older jax: list of dicts
            cost = cost[0] if cost else {}
        assert cost.get("flops", 0) > 0
        print("multipod microcell ok", cost.get("flops"))
    """, n_dev=8)

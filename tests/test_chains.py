"""First-class compaction chains: tagging, ledger, scheduler, parity.

The paper's §3 diagnosis — tail latency is governed by chain *width* (L0
tiering fan-in) and *length* (levels a cascade traverses before the
stall clears) — requires chains to be real runtime objects.  This suite
pins:

* chain invariants under paranoid mode: acyclic parent lineage,
  child-after-parent scheduling, width/length matching the job topology,
  ledger/job agreement;
* tiering L0 chains wider than incremental-L0 chains, and vlsm's chains
  shorter than rocksdb's (effective length: stages forced per L0 relief,
  counting debt catch-up) on the same fillrandom stream;
* the chain-aware scheduler: L0-relieving chains outrank background
  sweeps, policy priority hooks order as documented, and turning the
  scheduler off (``chain_aware_sched=False``) changes timing only —
  never structure;
* read-parity: chain tagging must not perturb GET accounting — replayed
  byte-identical against the pre-LevelIndex seed capture.
"""

import hashlib
import itertools
import json
import math
from pathlib import Path

import numpy as np
import pytest

import repro.core.lsm as lsm_mod
import repro.core.sst as sst_mod
from repro.bench_kv.workloads import load_keys, make_run_c
from repro.core import DeviceModel, Simulator, get_policy, policies
from repro.core.lsm import Job
from repro.core.sim import ChainScheduler

SCALE = 1 << 17
LAM = SCALE / (64 << 20)


def _reset_counters():
    """Fresh process-global uid counters: bloom FP hashing mixes sst.uid
    and the ledger compares job uids across runs."""
    sst_mod._ids = itertools.count()
    lsm_mod._job_ids = itertools.count()
    lsm_mod._chain_ids = itertools.count()


def _fill(policy_name: str, n: int = 40_000, seed: int = 7, **cfg_kw):
    cfg = get_policy(policy_name).default_config(scale=SCALE)
    if cfg_kw:
        cfg = cfg.with_(**cfg_kw)
    _reset_counters()
    sim = Simulator(cfg, DeviceModel.scaled(LAM))
    keys = load_keys(n, seed)
    res = sim.run(np.zeros(n, np.uint8), keys,
                  np.arange(n, dtype=np.float64) / 1e6)
    return sim, res


# ------------------------------------------------------- chain invariants
@pytest.mark.parametrize("pname", policies.names())
def test_chain_topology_invariants(pname):
    """Ledger records agree with the scheduled job graph for every
    registered policy (paranoid mode also validates continuously)."""
    sim, _res = _fill(pname, n=25_000)
    st = sim.stats
    assert st.chains, "fillrandom must trigger compaction chains"
    by_chain: dict[int, list[Job]] = {}
    for j in sim.job_log:
        assert j.chain_id >= 0, "every scheduled job carries a chain id"
        if j.kind == "compact":
            by_chain.setdefault(j.chain_id, []).append(j)
    assert set(by_chain) == {c.chain_id for c in st.chains}, \
        "every compact job belongs to exactly one ledgered chain"
    for rec in st.chains:
        jobs = by_chain[rec.chain_id]
        assert [j.uid for j in jobs] == rec.job_uids
        assert rec.n_jobs == len(jobs)
        head = jobs[-1]
        # width/length match the job topology
        assert rec.width == (head.l0_consumed or head.n_in_ssts)
        assert rec.width >= 1
        assert rec.length == len({j.level for j in jobs}) >= 1
        assert rec.width_bytes == sum(j.total_bytes for j in jobs)
        uids = {j.uid for j in jobs}
        for j in jobs:
            # acyclic parent lineage, contained in the chain
            visited = {j.uid}
            p = j.parent_job
            while p is not None:
                assert p.uid in uids and p.uid not in visited
                visited.add(p.uid)
                p = p.parent_job
            # child never starts before its parent finishes
            if j.parent_job is not None:
                assert j.t_start >= j.parent_job.t_finish - 1e-9
        # the DES filled the temporal ledger
        assert math.isfinite(rec.t_start)
        assert rec.t_finish >= rec.t_start
        assert rec.critical_path_s >= 0.0
        if rec.trigger == "l0":
            assert head.level == 0, "an l0 chain's head relieves L0"


def test_flush_jobs_are_singleton_chains():
    sim, _res = _fill("vlsm", n=25_000)
    compact_chains = {c.chain_id for c in sim.stats.chains}
    for j in sim.job_log:
        if j.kind == "flush":
            assert j.chain_id >= 0
            assert j.parent_job is None
            assert j.chain_id not in compact_chains


# ------------------------------------------- paper claims (width, length)
def test_tiering_l0_chains_wider_than_incremental():
    """Tiering merges ALL of L0 at once (fan-in ~ l0_max_ssts); the
    incremental designs pop one SST (fan-in 1)."""
    sim_r, _ = _fill("rocksdb")
    for incremental in ("vlsm", "lsmi"):
        sim_i, _ = _fill(incremental)
        assert (sim_r.stats.mean_chain_fanin
                > sim_i.stats.mean_chain_fanin), incremental
        assert sim_i.stats.mean_chain_fanin == 1.0


def test_vlsm_chains_narrower_and_shorter_than_rocksdb():
    """The same fillrandom stream: vlsm's mean chain width (bytes AND
    fan-in) sits strictly below rocksdb's, and so does its chain length
    measured on equal footing (effective length folds the debt catch-up
    rocksdb defers into background sweeps back into the cascade)."""
    sim_v, _ = _fill("vlsm")
    sim_r, _ = _fill("rocksdb")
    assert sim_v.stats.mean_chain_fanin < sim_r.stats.mean_chain_fanin
    assert sim_v.stats.mean_chain_width < sim_r.stats.mean_chain_width
    assert (sim_v.stats.effective_chain_length
            < sim_r.stats.effective_chain_length)


def test_chain_stall_attribution_bounded():
    """L0 write-stop stalls are pinned on the chain clearing the awaited
    slot; the attributed total can never exceed the run's stall total."""
    sim, res = _fill("rocksdb")
    attributed = sum(c.stall_s for c in sim.stats.chains)
    assert attributed > 0.0, "a flood fill must hit the write-stop gate"
    assert attributed <= res.stall_total + 1e-9


# ------------------------------------------------- the chain-aware pool
def test_chain_scheduler_orders_l0_relief_first():
    """One slot serializes, so priority order is observable: the
    L0-relieving chain (emitted later!) runs before the background sweep,
    and the intra-chain dependency edge is honoured."""
    pool = ChainScheduler(1)
    bg = Job("compact", 2, 1000, 1000, 2, 2, chain_id=101)
    deep = Job("compact", 1, 1000, 1000, 2, 2, chain_id=102)
    head = Job("compact", 0, 1000, 1000, 4, 2, deps=[deep], chain_id=102,
               parent_job=deep, l0_consumed=4)
    pol = get_policy("rocksdb")
    cfg = pol.default_config(scale=SCALE)
    pool.schedule_batch([(bg, 1.0), (deep, 1.0), (head, 1.0)], 0.0, 0,
                        lambda jobs: pol.chain_priority(cfg, jobs[-1], jobs))
    assert deep.t_start < head.t_start, "parent before child"
    assert head.t_start >= deep.t_finish - 1e-12
    assert bg.t_start >= head.t_finish - 1e-12, \
        "background sweep must wait for the L0-relieving chain"


def test_policy_chain_priority_hooks():
    """vlsm: narrowest chain first among L0 peers; lazy: wholesale
    intermediate moves behind bottom-level greedy picks; both: L0 relief
    always outranks background work."""
    vl = get_policy("vlsm")
    cfg = vl.default_config(scale=SCALE)
    narrow = Job("compact", 0, 100, 100, 1, 1, chain_id=1, l0_consumed=1)
    wide = Job("compact", 0, 9999, 9999, 1, 4, chain_id=2, l0_consumed=1)
    bg = Job("compact", 2, 10, 10, 1, 1, chain_id=3)
    assert (vl.chain_priority(cfg, narrow, [narrow])
            < vl.chain_priority(cfg, wide, [wide])
            < vl.chain_priority(cfg, bg, [bg]))

    lz = get_policy("lazy")
    lcfg = lz.default_config(scale=SCALE)
    l0 = Job("compact", 0, 100, 100, 4, 1, chain_id=4, l0_consumed=4)
    bottom = Job("compact", lcfg.max_levels - 2, 100, 100, 1, 1, chain_id=5)
    mid = Job("compact", 1, 100, 100, 3, 3, chain_id=6)
    assert (lz.chain_priority(lcfg, l0, [l0])
            < lz.chain_priority(lcfg, bottom, [bottom])
            < lz.chain_priority(lcfg, mid, [mid]))


def test_chain_sched_toggle_changes_timing_only():
    """chain_aware_sched=False restores FIFO drain order: the eager
    structure — every ledgered chain, every byte — is identical; only
    the DES's device timing may move."""
    sim_on, _ = _fill("rocksdb", n=30_000)
    sim_off, _ = _fill("rocksdb", n=30_000, chain_aware_sched=False)

    def structural(sim):
        return [(c.chain_id, c.trigger, c.width, c.length, c.width_bytes,
                 tuple(c.stage_bytes), tuple(c.job_uids))
                for c in sim.stats.chains]

    assert structural(sim_on) == structural(sim_off)
    assert sim_on.stats.io_amp == sim_off.stats.io_amp
    assert sim_on.stats.merged_keys == sim_off.stats.merged_keys


# ------------------------------------------------------------ read parity
def test_chain_tagging_keeps_read_parity_byte_identical():
    """Replay one seed-capture case directly: chain tagging and the
    chain-aware scheduler must not perturb GET accounting by a byte
    (the full 5-policy x 3-workload sweep lives in test_read_parity)."""
    ref = json.loads((Path(__file__).parent / "data"
                      / "read_parity_seed.json").read_text())
    meta = ref["meta"]
    want = ref["cases"]["vlsm:run_c"]
    pop = np.unique(load_keys(meta["n_pop"], seed=meta["pop_seed"]))
    spec = make_run_c(pop, meta["n_run"], dist=meta["dist"])
    op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                               spec.op_types])
    keys = np.concatenate([pop, spec.keys])
    arrivals = np.arange(op_types.shape[0], dtype=np.float64) / meta["rate"]
    _reset_counters()
    cfg = get_policy("vlsm").default_config(scale=meta["scale"])
    sim = Simulator(cfg, DeviceModel.scaled(meta["scale"] / (64 << 20)),
                    n_regions=meta["n_regions"])
    res = sim.run(op_types, keys, arrivals)
    g = res.op_types == 1
    reads = res.get_reads[g].astype(np.int64)
    probed = res.get_probed[g].astype(np.int64)
    assert hashlib.sha256(reads.tobytes()).hexdigest() == want["reads_sha256"]
    assert (hashlib.sha256(probed.tobytes()).hexdigest()
            == want["probed_sha256"])
    assert int(sim.stats.device_reads) == want["device_reads"]

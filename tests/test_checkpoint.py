"""LSM checkpoint store: incremental saves, restore parity, versioned
restore, GC, reshard-on-restore."""

import numpy as np
import pytest

from repro.checkpoint import LSMCheckpointStore


def _tree(seed, shape=(100, 50)):
    rng = np.random.default_rng(seed)
    return {"layers": {"w": rng.standard_normal(shape).astype(np.float32)},
            "bias": rng.standard_normal(shape[1]).astype(np.float32),
            "step": np.asarray(seed)}


def test_roundtrip_and_incremental(tmp_path):
    store = LSMCheckpointStore(tmp_path, page_bytes=4096)
    t0 = _tree(0)
    s0 = store.save(0, t0)
    assert s0["pages_written"] == s0["pages_total"] > 0
    # identical tree -> zero pages written (page hashing)
    s1 = store.save(1, t0)
    assert s1["pages_written"] == 0
    # mutate one page worth of one leaf
    t2 = {**t0, "bias": t0["bias"] + 1}
    s2 = store.save(2, t2)
    assert 0 < s2["pages_written"] < s0["pages_total"]

    got, stats = store.restore(2, treedef_like=t2)
    for k in ("bias",):
        np.testing.assert_array_equal(got[k], t2[k])
    np.testing.assert_array_equal(got["layers"]["w"], t0["layers"]["w"])
    assert stats["segments_touched"] <= stats["segments_total"]


def test_restore_older_step(tmp_path):
    store = LSMCheckpointStore(tmp_path, page_bytes=2048)
    trees = [_tree(i) for i in range(3)]
    for i, t in enumerate(trees):
        store.save(i, t)
    for i in range(3):
        got, _ = store.restore(i, treedef_like=trees[i])
        np.testing.assert_array_equal(got["layers"]["w"],
                                      trees[i]["layers"]["w"])


def test_manifest_reload(tmp_path):
    store = LSMCheckpointStore(tmp_path, page_bytes=2048)
    t = _tree(5)
    store.save(0, t)
    # a new store over the same dir must restore identically (recovery)
    store2 = LSMCheckpointStore(tmp_path, page_bytes=2048)
    got, _ = store2.restore(0, treedef_like=t)
    np.testing.assert_array_equal(got["layers"]["w"], t["layers"]["w"])


def test_reshard_on_restore(tmp_path):
    """Elastic restore: device_put under a (new) sharding."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    store = LSMCheckpointStore(tmp_path, page_bytes=2048)
    t = _tree(7)
    store.save(0, t)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * np.ndim(x)))), t)
    got, _ = store.restore(0, treedef_like=t, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(got["bias"]), t["bias"])


def test_index_uses_vlsm_policy(tmp_path):
    from repro.core import Policy
    store = LSMCheckpointStore(tmp_path, page_bytes=1024)
    assert store.index.cfg.policy == Policy.VLSM
    # churn enough versions to force index compactions, then verify the
    # tree invariants still hold (real LSM underneath)
    rng = np.random.default_rng(0)
    for i in range(12):
        store.save(i, {"w": rng.standard_normal((64, 64)).astype(np.float32)})
    store.index.check_invariants()
    got, stats = store.restore(11, treedef_like={"w": np.zeros((64, 64),
                                                               np.float32)})
    assert got["w"].shape == (64, 64)
    # bounded restore read-amp: newest step touches few segments
    assert stats["segments_touched"] <= 3

"""Heap-loop vs batched fleet engine parity.

The serial :class:`Simulator` is the correctness oracle; the two-phase
:class:`FleetEngine` must reproduce it exactly — byte-identical store
behaviour (reads, probes, stall counts) and per-op latencies to float
tolerance — across every registered policy, shard counts, and arrival
schedules sharing one structural replay.

Both engines draw SST/job/chain uids from module-level counters (slot-0
trees keep the seed-compatible shared stream), and uids seed blooms: the
counters must be rewound between engines or the second run's bloom
false-positive draws differ.  ``reset_uid_counters`` is that idiom.
"""

import numpy as np
import pytest

from repro.core import (DeviceModel, FleetEngine, Simulator, SweepPoint,
                        fleet_sweep, get_policy, reset_uid_counters,
                        serial_sweep)

SCALE = 1 << 17
DEV = DeviceModel.scaled(1 / 1024)
POLICIES = ("vlsm", "rocksdb", "rocksdb_io", "adoc", "lsmi", "lazy")


def _workload(seed=3, n=7_000, read_frac=0.3, rate=5_000.0):
    rng = np.random.default_rng(seed)
    ops = (rng.random(n) < read_frac).astype(np.uint8)
    keys = rng.integers(0, SCALE, n).astype(np.int64)
    arr = np.arange(n, dtype=np.float64) / rate
    return ops, keys, arr


def _assert_parity(r_ser, r_fle):
    # structural replay byte-identical...
    assert np.array_equal(r_ser.get_reads, r_fle.get_reads)
    assert np.array_equal(r_ser.get_probed, r_fle.get_probed)
    # ...temporal pass event-identical...
    assert r_ser.n_stalls == r_fle.n_stalls
    assert r_ser.stall_events == r_fle.stall_events
    assert abs(r_ser.stall_total - r_fle.stall_total) < 1e-12
    # ...latency within float tolerance (one batched scan vs n serial ones)
    assert float(np.max(np.abs(r_fle.latency - r_ser.latency))) < 1e-9
    assert abs(r_fle.makespan - r_ser.makespan) < 1e-9


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("k", (1, 4))
def test_fleet_matches_heap(policy, k):
    """Every registered policy, single- and multi-shard: the fleet engine
    is a drop-in for the serial heap loop."""
    cfg = get_policy(policy).default_config(scale=SCALE).with_(n_shards=k)
    ops, keys, arr = _workload()
    reset_uid_counters()
    r_ser = Simulator(cfg, DEV).run(ops, keys, arr)
    reset_uid_counters()
    r_fle = FleetEngine(cfg, DEV).run(ops, keys, arr)
    _assert_parity(r_ser, r_fle)


def test_multi_rate_passes_match_per_rate_heap_runs():
    """One structural replay, many temporal passes: every pass on the
    rate axis must equal a fresh serial run at that rate — including the
    passes run AFTER other rates (no temporal state bleeds through)."""
    from repro.kernels.lindley_scan.ops import lindley_batch_np
    cfg = get_policy("vlsm").default_config(scale=SCALE).with_(n_shards=2)
    ops, keys, _ = _workload()
    n = ops.shape[0]
    rates = (2_000.0, 20_000.0, 5_000.0)
    arrs = [np.arange(n, dtype=np.float64) / r for r in rates]

    serial = []
    for a in arrs:
        reset_uid_counters()
        serial.append(Simulator(cfg, DEV).run(ops, keys, a))

    reset_uid_counters()
    eng = FleetEngine(cfg, DEV)
    eng.prepare_structural(ops, keys)
    pendings = [eng.temporal_pass(a) for a in arrs]
    for r_ser, pd in zip(serial, pendings):
        deps = lindley_batch_np([q[0] for q in pd.queues],
                                [q[1] for q in pd.queues], backend="jnp")
        _assert_parity(r_ser, eng.finalize(deps, pending=pd))


def test_fleet_sweep_matches_serial_sweep():
    """The matrix drivers: fleet_sweep's single batched program equals
    serial_sweep run by run (both rewind uid counters per engine, so the
    comparison needs no external setup)."""
    ops, keys, _ = _workload(n=5_000)
    n = ops.shape[0]
    grid = [np.arange(n, dtype=np.float64) / r for r in (3_000.0, 12_000.0)]
    points = [SweepPoint(label=f"{p}/{k}",
                         cfg=get_policy(p).default_config(scale=SCALE)
                         .with_(n_shards=k),
                         device=DEV, op_types=ops, keys=keys,
                         arrivals_grid=grid)
              for p in ("vlsm", "rocksdb") for k in (1, 2)]
    fr = fleet_sweep(points, backend="jnp")
    sr = serial_sweep(points)
    assert len(fr) == len(points) and all(len(x) == 2 for x in fr)
    for pf, ps in zip(fr, sr):
        for a, b in zip(pf, ps):
            _assert_parity(b, a)


def test_fleet_pallas_backend_matches_jnp():
    """The Pallas blocked-scan kernel (interpret mode here) and the
    vmapped jnp oracle agree through the full engine path."""
    cfg = get_policy("vlsm").default_config(scale=SCALE)
    ops, keys, arr = _workload(n=3_000)
    reset_uid_counters()
    r_jnp = FleetEngine(cfg, DEV).run(ops, keys, arr, backend="jnp")
    reset_uid_counters()
    r_pal = FleetEngine(cfg, DEV).run(ops, keys, arr, backend="pallas")
    assert float(np.max(np.abs(r_jnp.latency - r_pal.latency))) < 1e-9


@pytest.mark.slow
def test_fleet_full_matrix_parity():
    """The full bench-shaped matrix — every policy × shard count × a
    rate axis — pinned to the serial oracle run by run.  Excluded from
    the default run (see pyproject addopts); ``pytest -m slow``."""
    ops, keys, _ = _workload()
    n = ops.shape[0]
    grid = [np.arange(n, dtype=np.float64) / r
            for r in (2_000.0, 6_000.0, 18_000.0)]
    points = [SweepPoint(label=f"{p}/{k}",
                         cfg=get_policy(p).default_config(scale=SCALE)
                         .with_(n_shards=k),
                         device=DEV, op_types=ops, keys=keys,
                         arrivals_grid=grid)
              for p in POLICIES for k in (1, 2, 4, 16)]
    fr = fleet_sweep(points, backend="numpy")
    sr = serial_sweep(points)
    for pf, ps in zip(fr, sr):
        for a, b in zip(pf, ps):
            _assert_parity(b, a)


def test_fleet_empty_shards():
    """Shards no key routes to: empty windows, empty Lindley queues."""
    cfg = get_policy("vlsm").default_config(scale=SCALE).with_(n_shards=4)
    n = 3_000
    rng = np.random.default_rng(0)
    ops = (rng.random(n) < 0.3).astype(np.uint8)
    keys = np.full(n, 12_345, np.int64)      # ONE key: one shard gets all
    arr = np.arange(n, dtype=np.float64) / 4_000.0
    reset_uid_counters()
    r_ser = Simulator(cfg, DEV).run(ops, keys, arr)
    reset_uid_counters()
    r_fle = FleetEngine(cfg, DEV).run(ops, keys, arr)
    _assert_parity(r_ser, r_fle)

"""Fixture mechanism file that branches on a policy identity."""


def pick_l0_strategy(cfg):
    if cfg.policy == "vlsm":  # expect-lint: L102
        return "incremental"
    return "tiering"

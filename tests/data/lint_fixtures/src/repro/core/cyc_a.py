"""Half of the fixture import cycle."""

from . import cyc_b  # expect-lint: L106


def ping():
    return cyc_b.pong()

"""Fixture determinism hazards, one line per rule."""

import time

import numpy as np


def stamp():
    return time.time()  # expect-lint: D201


def draw():
    return np.random.rand(3)  # expect-lint: D202


def walk():
    out = []
    for x in {1, 2, 3}:  # expect-lint: D203
        out.append(x)
    return out


def order(xs):
    return sorted(xs, key=id)  # expect-lint: D204


def total():
    return sum({0.1, 0.2, 0.3})  # expect-lint: D205

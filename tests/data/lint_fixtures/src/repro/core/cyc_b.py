"""Other half of the fixture import cycle."""

from . import cyc_a


def pong():
    return cyc_a.ping()

"""Unit-dimension fixtures: one violation per U5xx rule.

Parameter names carry the units (the same suffix convention the real
tree uses); each function isolates exactly one rule.
"""


def mixed_add(p99_ms: float, stall_total_s: float) -> float:
    return p99_ms + stall_total_s  # expect-lint: U501


def bad_assign(stall_total_s: float) -> float:
    lat_ms = stall_total_s  # expect-lint: U502
    return lat_ms


def double_convert(p99_ms: float) -> float:
    return p99_ms * 1e3  # expect-lint: U503


def unsuffixed_row(stall_total_s: float) -> dict:
    return {
        "bench": "units_bad",
        "stall": stall_total_s,  # expect-lint: U504
    }

"""Fixture mechanism file that imports a concrete policy module."""

from .policies import vlsm  # expect-lint: L101


def engine_default():
    return vlsm.VLSMFixturePolicy()

"""Fixture contract violations: drifted signature, typo'd hook, and a
registered policy missing its required override."""

from .base import CompactionPolicy


def register(policy):
    """Stand-in for the real registry entry point."""


class SigMismatchPolicy(CompactionPolicy):
    name = "sig"

    def default_config(self):
        return None

    def level_target(self, cfg):  # expect-lint: C301
        return 2

    def chain_prioriy(self, cfg):  # expect-lint: C302
        return 0


class NoDefaultPolicy(CompactionPolicy):  # expect-lint: C303
    name = "nodefault"


register(NoDefaultPolicy())

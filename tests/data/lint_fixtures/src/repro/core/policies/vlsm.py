"""Fixture concrete policy: clean, and the L101/L102 bait (a mechanism
file that imports this module, or mentions its registry name, is in
violation)."""

from .base import CompactionPolicy


class VLSMFixturePolicy(CompactionPolicy):
    name = "vlsm"

    def default_config(self):
        return None

"""Fixture contract surface: a miniature CompactionPolicy base."""


MECHANISM_PRIMITIVES = ("emit_compact_job", "merge_down")
INDEX_QUERIES = ("fences",)
L0_INDEX_MUTATORS = ("l0_clear",)


class CompactionPolicy:
    """Fixture base class.

    .. contract-table-start  # expect-lint: C304
    (this table is deliberately stale)
    .. contract-table-end
    """

    name = ""

    def default_config(self):
        raise NotImplementedError

    def level_target(self, cfg, level):
        return 1

    def compact_l0(self, tree, deps):
        return None

    def _tiering_l0(self, tree, deps):
        return None

"""Fixture policy that reaches past the contract surface."""

from .base import CompactionPolicy


class ImpurePolicy(CompactionPolicy):
    name = "impure"

    def default_config(self):
        return None

    def compact_l0(self, tree, deps):
        tree.seal_memtable()  # expect-lint: L103
        tree.levels[1] = []  # expect-lint: L104
        return None

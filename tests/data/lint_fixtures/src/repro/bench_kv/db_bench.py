"""Bench-schema fixtures: the emitters the B6xx rules diff against the
fixture ``docs/benchmarks.md`` (B601 — stale generated table) and the
fixture ``BENCH_dbbench.json`` (B602 — the ``alpha`` rows are missing
``p99_get_ms``).  ``alpha`` stores seconds under the unsuffixed key
``stall`` while ``beta`` stores milliseconds under the same name —
the B603 cross-family unit conflict (U504 is deliberately suppressed
on those lines; it has its own fixture in ``core/units_bad.py``).
"""


def alpha(n_ops: int, stall_total_s: float, wall: float) -> dict:
    return {  # expect-lint: B602
        "bench": "alpha",
        "ops": n_ops,
        "p99_get_ms": 12.5,
        "stall": stall_total_s,  # lint-ok: U504
        "wall_clock_s": wall,
    }


def beta(n_ops: int, p99_ms: float, wall: float) -> dict:
    return {  # expect-lint: B603
        "bench": "beta",
        "ops": n_ops,
        "stall": p99_ms,  # lint-ok: U504
        "wall_clock_s": wall,
    }

"""Fixture kernel that reaches up into the engine layer."""

from repro.core import lsm  # expect-lint: L105


def kernel():
    return lsm

"""Rule-family-4 fixture: a corrupted DES schedule the runtime sanitizer
must reject.  ``tests/test_analysis.py`` runs this in a subprocess with
``REPRO_SANITIZE=1`` and expects a non-zero exit (S403: two jobs
occupying the same (tree, level) compaction slot at overlapping times).
"""

import os
from dataclasses import dataclass

os.environ["REPRO_SANITIZE"] = "1"

from repro.analysis.sanitizer import maybe_sanitizer  # noqa: E402


@dataclass
class FakeJob:
    t_start: float
    t_finish: float
    kind: str = "compact"
    level: int = 1
    chain_id: int = 7
    parent_job: object = None
    scheduled: bool = True


sanitizer = maybe_sanitizer()
assert sanitizer is not None, "REPRO_SANITIZE=1 must enable the sanitizer"
sanitizer.on_schedule(0, FakeJob(t_start=0.0, t_finish=5.0))
# same tree, same source level, starts while the slot is still busy:
sanitizer.on_schedule(0, FakeJob(t_start=2.0, t_finish=6.0))
raise SystemExit("sanitizer failed to reject an overlapping slot schedule")

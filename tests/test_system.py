"""End-to-end behaviour tests: the paper's headline claims, the training
loop with failure injection, and the serving loop."""

import numpy as np
import pytest

from repro.bench_kv import make_load_a, run_ycsb, sustainable_throughput
from repro.core import LSMConfig

SCALE = 1 << 18


def test_paper_headline_tail_latency():
    """vLSM cuts P99 and max-stall versus RocksDB at 60% of each system's
    sustainable rate (§5 methodology), while chain width shrinks by an
    order of magnitude (paper §6.2)."""
    spec = make_load_a(80_000)
    cfg_v = LSMConfig.vlsm_default(scale=SCALE)
    cfg_r = LSMConfig.rocksdb_default(scale=SCALE)
    v = run_ycsb(cfg_v, spec,
                 0.6 * sustainable_throughput(cfg_v, spec, scale=SCALE),
                 scale=SCALE)
    r = run_ycsb(cfg_r, spec,
                 0.6 * sustainable_throughput(cfg_r, spec, scale=SCALE),
                 scale=SCALE)
    assert v.sim.stats.max_chain_width * 5 < r.sim.stats.max_chain_width
    assert v.sim.stall_max <= r.sim.stall_max
    assert v.sim.p99 <= r.sim.p99


def test_phi64_failure_mode():
    """Fig 13: at Φ=64 (4 MB SSTs) the good-vSST supply collapses."""
    spec = make_load_a(60_000)
    cfg32 = LSMConfig.vlsm_default(scale=SCALE)              # Φ=32
    cfg64 = LSMConfig.vlsm_default(scale=SCALE, sst_frac=16).with_(phi=64)
    r32 = run_ycsb(cfg32, spec, 2500.0, scale=SCALE)
    r64 = run_ycsb(cfg64, spec, 2500.0, scale=SCALE)
    f32 = r32.sim.stats.vssts_good / max(
        1, r32.sim.stats.vssts_good + r32.sim.stats.vssts_poor)
    f64 = r64.sim.stats.vssts_good / max(
        1, r64.sim.stats.vssts_good + r64.sim.stats.vssts_poor)
    assert f32 > f64


@pytest.mark.xfail(
    reason="pre-existing seed failure: at smoke scale (24 steps, batch 4) "
           "the loss-decrease assertion sits at noise level (~6.2604 vs "
           "~6.2577 — a 0.04% gap); the restart/restore machinery it "
           "exercises passes, only the progress check is flaky",
    strict=False)
def test_train_loop_with_failure_and_restore(tmp_path):
    from repro.launch.train import run
    out = run("qwen3_1_7b", smoke=True, steps=24, batch=4, seq=32,
              ckpt_every=8, ckpt_dir=tmp_path, fail_at=18, log_every=100)
    assert out["restarts"] == 1
    assert np.isfinite(out["losses"]).all()
    # training makes progress on the learnable synthetic stream
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


def test_serve_loop_prefix_hits():
    from repro.launch.serve import run
    out = run("gemma3_1b", smoke=True, n_requests=6, decode_tokens=4)
    s = out["stats"]
    assert s["prefix_hits"] >= 3          # shared prefixes hit after warmup
    assert all(len(o) == 4 for o in out["outputs"])

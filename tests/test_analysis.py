"""repro-lint end to end: rule fixtures, CLI exit codes, baseline
workflow, the generated contract table, and the runtime DES schedule
sanitizer (including fleet-vs-serial parity with it enabled).

The fixture protocol: every intentional violation in
``tests/data/lint_fixtures/`` carries an ``# expect-lint: <RULE>``
marker on its line.  The analyzer must fire exactly at the markers —
nothing missing, nothing extra — and must stay silent on the real
codebase (minus the checked-in baseline).
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (ScheduleSanitizer, ScheduleSanitizerError,
                            analyze_paths, analyze_repo, maybe_sanitizer)
from repro.analysis import catalog, schemas
from repro.analysis.contracts import (check_contract_table,
                                      generate_contract_table)
from repro.analysis.astutil import load_modules
from repro.analysis.findings import load_baseline, write_baseline
from repro.analysis.schemas import (CSV_FAMILY, check_schema_table,
                                    extract_variants, generate_schema_table,
                                    paranoid_validate_rows,
                                    validate_emitted_row)
from repro.core import (FleetEngine, Simulator, get_policy,
                        reset_uid_counters)
from repro.core.types import DeviceModel

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "data" / "lint_fixtures"
BASE_PY = ROOT / "src" / "repro" / "core" / "policies" / "base.py"
BENCH_DOC = ROOT / "docs" / "benchmarks.md"

# every statically-checkable rule must have a fixture (the S4xx runtime
# sanitizer rules are exercised by the sanitizer tests below instead)
ALL_RULES = set(catalog.STATIC_RULES)
_MARKER = re.compile(r"#\s*expect-lint:\s*([A-Z]\d{3})")


def _sub_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    return env


def _run_cli(*args, env=None):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=ROOT,
        env=env or _sub_env())


def _expected_markers() -> set[tuple[str, str, int]]:
    expected = set()
    files = sorted((FIXTURES / "src").rglob("*.py")) \
        + sorted((FIXTURES / "docs").rglob("*.md"))
    for f in files:
        rel = f.relative_to(FIXTURES).as_posix()
        for lineno, text in enumerate(f.read_text().splitlines(), 1):
            for rule in _MARKER.findall(text):
                expected.add((rule, rel, lineno))
    return expected


# ---------------------------------------------------------------- fixtures
def test_every_rule_has_a_fixture_marker():
    rules = {r for r, _p, _l in _expected_markers()}
    assert rules == ALL_RULES


def test_fixture_findings_match_markers_exactly():
    """Each rule fires exactly at its marker — no silent rules, no
    spurious findings anywhere else in the fixture tree."""
    findings = analyze_paths(FIXTURES)
    actual = {(f.rule, f.path, f.line) for f in findings}
    assert actual == _expected_markers()


@pytest.mark.parametrize("family,rules", [
    ("layering", {"L101", "L102", "L103", "L104", "L105", "L106"}),
    ("determinism", {"D201", "D202", "D203", "D204", "D205"}),
    ("contracts", {"C301", "C302", "C303", "C304"}),
    ("units", {"U501", "U502", "U503", "U504"}),
    ("schemas", {"B601", "B602", "B603"}),
])
def test_each_family_fails_cli_on_fixture(family, rules):
    """Acceptance: every rule family has a fixture that makes the CLI
    exit 1, and the JSON report carries exactly that family's rules."""
    res = _run_cli("--root", str(FIXTURES), "--rules", family,
                   "--format", "json")
    assert res.returncode == 1, res.stderr
    report = json.loads(res.stdout)
    assert {f["rule"] for f in report["fresh"]} == rules


def test_sanitizer_fixture_exits_nonzero():
    """Rule family 4: a corrupted schedule must crash under
    REPRO_SANITIZE=1."""
    res = subprocess.run(
        [sys.executable, str(FIXTURES / "sanitizer_violation.py")],
        capture_output=True, text=True, env=_sub_env())
    assert res.returncode != 0
    assert "S403" in res.stderr


# ------------------------------------------------------------ real codebase
def test_repo_is_clean_minus_baseline():
    findings = analyze_repo()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_exits_zero_on_repo():
    res = _run_cli()
    assert res.returncode == 0, res.stdout + res.stderr


def test_baseline_suppresses_known_findings(tmp_path):
    """The baseline workflow: accept the fixture findings, rerun, and
    the gate goes green without touching the code."""
    findings = analyze_paths(FIXTURES)
    assert findings
    baseline_path = tmp_path / "baseline.json"
    write_baseline(baseline_path, findings)
    baseline = load_baseline(baseline_path)
    assert all(baseline.covers(f) for f in findings)
    res = _run_cli("--root", str(FIXTURES), "--baseline",
                   str(baseline_path))
    assert res.returncode == 0, res.stdout


def test_fingerprint_survives_line_churn():
    f = analyze_paths(FIXTURES)[0]
    moved = type(f)(rule=f.rule, family=f.family, path=f.path,
                    line=f.line + 40, message=f.message, hint=f.hint,
                    snippet="  " + f.snippet + "  ")
    assert moved.fingerprint() == f.fingerprint()


# -------------------------------------------------------- contract table
def test_contract_table_is_current():
    """C304 on the real base.py: the checked-in table must match what
    the generator produces (they share one implementation, so this is
    the no-drift guarantee)."""
    [mod] = load_modules(BASE_PY.parent, [BASE_PY])
    assert check_contract_table(mod) == []
    table = generate_contract_table(mod)
    assert "default_config(scale, **kw)" in table
    assert "merge_down" in table


def test_write_contract_table_is_idempotent():
    before = BASE_PY.read_text()
    res = _run_cli("--write-contract-table")
    assert res.returncode == 0
    assert BASE_PY.read_text() == before


# --------------------------------------------------------- bench schemas
def test_schema_table_is_current():
    """B601 on the real docs/benchmarks.md: the checked-in generated
    block must match what the extractor produces from the emitters."""
    variants = extract_variants(ROOT)
    assert check_schema_table(ROOT, variants) == []
    table = generate_schema_table(variants)
    assert "shard_sweep" in table
    assert "run_csv" in table
    assert "`p99_get_ms`:ms" in table


def test_write_schema_table_is_idempotent():
    before = BENCH_DOC.read_text()
    res = _run_cli("--write-schema-table")
    assert res.returncode == 0
    assert BENCH_DOC.read_text() == before


def _schema_inputs_copy(tmp_path: Path) -> Path:
    """Copy the fixed inputs the schemas family diffs into a tmp root."""
    for rel in ("src/repro/bench_kv/db_bench.py", "benchmarks/common.py",
                "docs/benchmarks.md", "BENCH_dbbench.json"):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        dst.write_text((ROOT / rel).read_text())
    return tmp_path


def test_renamed_emitter_key_fires_schema_rules(tmp_path):
    """Acceptance: renaming an emitted key (p99_get_ms) makes both the
    doc table (B601) and the JSON cross-check (B602) fail, each with a
    file:line finding."""
    root = _schema_inputs_copy(tmp_path)
    emitter = root / "src" / "repro" / "bench_kv" / "db_bench.py"
    src = emitter.read_text()
    assert '"p99_get_ms"' in src
    emitter.write_text(src.replace('"p99_get_ms"', '"p99_renamed_ms"'))
    findings = schemas.check(root)
    rules = {f.rule for f in findings}
    assert {"B601", "B602"} <= rules, [f.format() for f in findings]
    for f in findings:
        if f.rule == "B601":
            assert f.path == "docs/benchmarks.md" and f.line > 0
        if f.rule == "B602":
            assert f.path == "src/repro/bench_kv/db_bench.py"
            assert f.line > 0


def test_paranoid_row_validation(monkeypatch):
    good = {"name": "x", "value": 1.0, "derived": "", "wall_clock_s": 0.1}
    bad = {"name": "x", "value": 1.0}
    monkeypatch.delenv("REPRO_PARANOID_CHECKS", raising=False)
    paranoid_validate_rows([bad], family=CSV_FAMILY, root=ROOT)  # gated off
    monkeypatch.setenv("REPRO_PARANOID_CHECKS", "1")
    paranoid_validate_rows([good], family=CSV_FAMILY, root=ROOT)
    with pytest.raises(ValueError, match=CSV_FAMILY):
        paranoid_validate_rows([bad], family=CSV_FAMILY, root=ROOT)
    # families the extractor has never seen stay free-form
    validate_emitted_row({"bench": "no_such_family"}, root=ROOT)


# ------------------------------------------------------------ CLI surface
def test_explain_cli():
    res = _run_cli("--explain", "U501")
    assert res.returncode == 0, res.stderr
    assert "U501" in res.stdout
    assert "unit" in res.stdout.lower()
    res = _run_cli("--explain", "Z999")
    assert res.returncode == 2
    assert "Z999" in res.stderr


def test_explain_covers_every_rule():
    for rule_id in catalog.CATALOG:
        text = catalog.explain(rule_id)
        assert text and rule_id in text


def test_github_format_emits_error_annotations():
    res = _run_cli("--root", str(FIXTURES), "--rules", "units",
                   "--format", "github")
    assert res.returncode == 1
    assert ("::error file=src/repro/core/units_bad.py,line=9,"
            "title=repro-lint U501::") in res.stdout


def test_units_and_schemas_clean_on_repo():
    res = _run_cli("--rules", "units,schemas")
    assert res.returncode == 0, res.stdout + res.stderr


# ------------------------------------------------------------- sanitizer
def test_maybe_sanitizer_env_gate(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert maybe_sanitizer() is None
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert maybe_sanitizer() is None
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert isinstance(maybe_sanitizer(), ScheduleSanitizer)


class _Job:
    def __init__(self, **kw):
        self.kind = "compact"
        self.level = 1
        self.chain_id = 3
        self.parent_job = None
        self.scheduled = True
        self.t_start = 0.0
        self.t_finish = 1.0
        self.__dict__.update(kw)


def test_sanitizer_rules_unit():
    san = ScheduleSanitizer()
    san.on_event(0, 1.0)
    san.on_event(1, 0.5)          # other tree: independent clock
    with pytest.raises(ScheduleSanitizerError, match="S401"):
        san.on_event(0, 0.9)

    san = ScheduleSanitizer()
    san.on_gate(0, 2.0)
    with pytest.raises(ScheduleSanitizerError, match="S404"):
        san.on_gate(0, 1.0)

    san = ScheduleSanitizer()
    parent = _Job(t_start=0.0, t_finish=5.0)
    child = _Job(t_start=4.0, t_finish=6.0, level=2, parent_job=parent)
    san.on_schedule(0, parent)
    with pytest.raises(ScheduleSanitizerError, match="S402"):
        san.on_schedule(0, child)

    san = ScheduleSanitizer()
    san.on_schedule(0, _Job(t_start=0.0, t_finish=5.0))
    san.on_schedule(1, _Job(t_start=1.0, t_finish=2.0))  # other tree: ok
    san.on_schedule(0, _Job(t_start=1.0, t_finish=2.0, level=2))  # ok
    with pytest.raises(ScheduleSanitizerError, match="S403"):
        san.on_schedule(0, _Job(t_start=4.0, t_finish=9.0))

    san.reset()
    san.on_schedule(0, _Job(t_start=4.0, t_finish=9.0))  # fresh timeline


def _workload(seed=3, n=6_000, read_frac=0.3, rate=5_000.0, scale=1 << 20):
    rng = np.random.default_rng(seed)
    ops = (rng.random(n) < read_frac).astype(np.uint8)
    keys = rng.integers(0, scale, n).astype(np.int64)
    arr = np.arange(n, dtype=np.float64) / rate
    return ops, keys, arr


def test_sanitizer_wired_into_simulator(monkeypatch):
    """The engine's hook sites are live: a clean run audits every event
    and job, and a violated chain edge is caught inside the real
    scheduling path."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg = get_policy("vlsm").default_config(scale=1 << 18)
    ops, keys, arr = _workload(n=3_000, scale=1 << 18)
    reset_uid_counters()
    sim = Simulator(cfg)
    sim.run(ops, keys, arr)
    assert sim.sanitizer is not None
    assert sim.sanitizer.events_checked > 0
    assert sim.sanitizer.jobs_checked > 0

    # a dep the pool never saw: the sanitizer rejects it from inside
    # SlotPool.schedule
    ghost_parent = _Job(t_start=0.0, t_finish=1e12, scheduled=True)
    orphan = _Job(level=7, parent_job=ghost_parent)
    orphan.deps = []
    orphan.uid = -1
    with pytest.raises(ScheduleSanitizerError, match="S402"):
        sim.compact_pool.schedule(orphan, ready=0.0, duration=1.0,
                                  region=0)


def test_sanitizer_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    cfg = get_policy("vlsm").default_config(scale=1 << 18)
    assert Simulator(cfg).sanitizer is None


@pytest.mark.parametrize("policy,k", [("vlsm", 1), ("rocksdb", 4)])
def test_fleet_parity_with_sanitizer(monkeypatch, policy, k):
    """Acceptance: fleet-vs-serial parity holds with REPRO_SANITIZE=1 —
    the sanitizer audits both engines and changes neither."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    dev = DeviceModel()
    cfg = get_policy(policy).default_config(scale=1 << 20).with_(n_shards=k)
    ops, keys, arr = _workload()
    reset_uid_counters()
    r_ser = Simulator(cfg, dev).run(ops, keys, arr)
    reset_uid_counters()
    eng = FleetEngine(cfg, dev)
    r_fle = eng.run(ops, keys, arr)
    assert eng.sanitizer is not None
    if k == 1:  # sharded runs delegate to per-shard engines' sanitizers
        assert eng.sanitizer.jobs_checked > 0
    assert r_ser.n_stalls == r_fle.n_stalls
    assert r_ser.stall_events == r_fle.stall_events
    assert float(np.max(np.abs(r_fle.latency - r_ser.latency))) < 1e-9
    assert abs(r_fle.makespan - r_ser.makespan) < 1e-9

"""Frozen-accounting regression: the batched LevelIndex read path must keep
the GET accounting byte-identical to the seed's scalar implementation.

``tests/data/read_parity_seed.json`` was captured from the pre-LevelIndex
code (per-op scalar ``LSMTree.get`` walk) on fixed-seed YCSB-A/B/C traces
for all five policies: sha256 over the per-op ``reads``/``probed``
sequences plus the Stats totals.  Any change to probe order, fence
selection, or the bloom false-positive model shows up here.
"""

import hashlib
import itertools
import json
from pathlib import Path

import numpy as np
import pytest

import repro.core.lsm as lsm_mod
import repro.core.sst as sst_mod
from repro.bench_kv.workloads import (load_keys, make_run_a, make_run_b,
                                      make_run_c)
from repro.core import DeviceModel, LSMConfig, Simulator

REF_PATH = Path(__file__).parent / "data" / "read_parity_seed.json"
REF = json.loads(REF_PATH.read_text())

POLICIES = {
    "vlsm": LSMConfig.vlsm_default,
    "rocksdb": LSMConfig.rocksdb_default,
    "rocksdb_io": LSMConfig.rocksdb_io_default,
    "adoc": LSMConfig.adoc_default,
    "lsmi": LSMConfig.lsmi_default,
}
WORKLOADS = {"run_a": make_run_a, "run_b": make_run_b, "run_c": make_run_c}

_TRACES = {}


def _trace(wname):
    if wname not in _TRACES:
        meta = REF["meta"]
        pop = np.unique(load_keys(meta["n_pop"], seed=meta["pop_seed"]))
        spec = WORKLOADS[wname](pop, meta["n_run"], dist=meta["dist"])
        op_types = np.concatenate([np.zeros(pop.shape[0], np.uint8),
                                   spec.op_types])
        keys = np.concatenate([pop, spec.keys])
        arrivals = np.arange(op_types.shape[0], dtype=np.float64) / meta["rate"]
        _TRACES[wname] = (op_types, keys, arrivals)
    return _TRACES[wname]


@pytest.mark.parametrize("wname", list(WORKLOADS))
@pytest.mark.parametrize("pname", list(POLICIES))
def test_read_accounting_matches_seed(pname, wname):
    meta = REF["meta"]
    want = REF["cases"][f"{pname}:{wname}"]
    op_types, keys, arrivals = _trace(wname)
    cfg = POLICIES[pname](scale=meta["scale"])
    # The bloom-FP hash mixes sst.uid (a process-global counter): the
    # reference was captured with fresh counters per case, so replay that.
    sst_mod._ids = itertools.count()
    lsm_mod._job_ids = itertools.count()
    sim = Simulator(cfg, DeviceModel.scaled(meta["scale"] / (64 << 20)),
                    n_regions=meta["n_regions"])
    res = sim.run(op_types, keys, arrivals)
    g = res.op_types == 1
    reads = res.get_reads[g].astype(np.int64)
    probed = res.get_probed[g].astype(np.int64)
    assert int(sim.stats.device_reads) == want["device_reads"]
    assert int(sim.stats.ops) == want["ops"]
    assert int(reads.shape[0]) == want["n_gets"]
    assert int(reads.sum()) == want["reads_sum"]
    assert int(probed.sum()) == want["probed_sum"]
    assert hashlib.sha256(reads.tobytes()).hexdigest() == want["reads_sha256"]
    assert (hashlib.sha256(probed.tobytes()).hexdigest()
            == want["probed_sha256"])

"""The registry-backed CompactionPolicy strategy layer.

* **Policy invariance** (the property the split rests on): data
  correctness is policy-independent — for every registered policy, the
  same random PUT/GET/DELETE/SCAN mix yields an *identical*
  ``merged_view()``, identical GET answers, and identical SCAN windows;
  only the structural arrangement (levels, chains, amplification) may
  differ.
* **Registry contract**: ``default_config`` round-trips through the
  registry, unknown names raise a helpful error listing the registered
  policies, the legacy ``Policy`` enum still resolves.
* **The lazy policy** (the proof-of-API sixth policy): registered, grows
  through multiple levels with wholesale intermediate moves, keeps every
  mechanism invariant.
* **paranoid_checks**: the flag wires ``check_invariants`` into
  ``drain_jobs`` (on in tests via conftest, off when disabled).
"""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propshim import HealthCheck, given, settings, st

from repro.core import (CompactionPolicy, DeviceModel, LSMConfig, LSMTree,
                        OpKind, Policy, Simulator, get_policy, policies)

SCALE = 1 << 16


def _grow(cfg, seed, n_ops=5000, with_reads=True):
    """Drive a fresh store through the DES with a mixed op stream."""
    rng = np.random.default_rng(seed)
    r = rng.random(n_ops)
    kinds = np.full(n_ops, OpKind.PUT, np.uint8)
    kinds[r < 0.15] = OpKind.DELETE
    if with_reads:
        kinds[(r >= 0.15) & (r < 0.30)] = OpKind.GET
        kinds[(r >= 0.30) & (r < 0.35)] = OpKind.SCAN
    keys = rng.integers(0, 1200, n_ops).astype(np.int64)
    lens = np.zeros(n_ops, np.int32)
    lens[kinds == OpKind.SCAN] = rng.integers(
        1, 40, int((kinds == OpKind.SCAN).sum()))
    sim = Simulator(cfg, DeviceModel.scaled(1 / 1024))
    res = sim.run(kinds, keys, np.arange(n_ops, dtype=np.float64) / 1e4,
                  scan_lens=lens)
    return sim.trees[0], res


# ------------------------------------------------------- policy invariance
@given(st.integers(0, 2**32))
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_policy_invariance_merged_view(seed):
    """Property: the user-visible store state after a random
    PUT/GET/DELETE/SCAN mix is identical under every registered policy."""
    views = {}
    probes = {}
    rng = np.random.default_rng(seed + 1)
    sample = rng.integers(0, 1200, 200).astype(np.int64)
    starts = rng.integers(0, 1200, 8).astype(np.int64)
    lens = rng.integers(1, 50, 8).astype(np.int32)
    for name in policies.names():
        cfg = get_policy(name).default_config(scale=SCALE)
        tree, _res = _grow(cfg, seed)
        tree.check_invariants()
        views[name] = tree.merged_view()
        seqs, _r, _p = tree.get_batch(sample)
        scan = tree.scan_batch(starts, lens)
        probes[name] = (seqs.tolist(), scan.scan_keys.tolist(),
                        scan.scan_seqs.tolist())
    ref_name = policies.names()[0]
    for name in policies.names()[1:]:
        assert views[name] == views[ref_name], \
            f"merged_view differs: {name} vs {ref_name}"
        assert probes[name] == probes[ref_name], \
            f"GET/SCAN answers differ: {name} vs {ref_name}"


# ----------------------------------------------------------- registry API
def test_registry_default_config_roundtrip():
    for name in policies.names():
        pol = get_policy(name)
        assert isinstance(pol, CompactionPolicy)
        cfg = pol.default_config(scale=SCALE)
        assert cfg.policy == name                       # name round-trips
        assert get_policy(cfg.policy) is pol            # and resolves back
        # the config delegates sizing/debt to the same policy object
        assert cfg.tiering == pol.tiering_l0
        assert cfg.level_target(2) == pol.level_target(cfg, 2)
        assert cfg.level_limit(2) == pol.level_limit(cfg, 2)


def test_registry_unknown_name_lists_policies():
    with pytest.raises(KeyError) as ei:
        get_policy("btree")
    msg = str(ei.value)
    for name in policies.names():
        assert name in msg, f"error should list registered policy {name!r}"


def test_registry_rejects_duplicates_and_unnamed():
    class Dup(CompactionPolicy):
        name = "vlsm"

    with pytest.raises(ValueError):
        policies.register(Dup())
    with pytest.raises(ValueError):
        policies.register(CompactionPolicy())           # empty name


def test_legacy_policy_enum_still_resolves():
    assert get_policy(Policy.VLSM).name == "vlsm"
    cfg = LSMConfig(policy=Policy.ROCKSDB)
    assert cfg.policy == "rocksdb" == Policy.ROCKSDB
    assert LSMTree(cfg).policy is get_policy("rocksdb")


def test_registered_policy_names_cover_paper_plus_lazy():
    assert set(policies.names()) >= {"vlsm", "rocksdb", "rocksdb_io",
                                     "adoc", "lsmi", "lazy"}


# ------------------------------------------------------------ lazy policy
def test_lazy_policy_fills_levels_with_wholesale_moves():
    cfg = get_policy("lazy").default_config(scale=SCALE)
    rng = np.random.default_rng(11)
    sim = Simulator(cfg, DeviceModel.scaled(1 / 1024))
    n = 40_000
    keys = rng.integers(0, 2**40, n).astype(np.int64)
    sim.run(np.zeros(n, np.uint8), keys, np.arange(n, dtype=np.float64) / 1e6)
    tree = sim.trees[0]
    tree.check_invariants()
    sizes = tree.level_sizes()
    assert sum(1 for s in sizes[1:] if s > 0) >= 2, sizes
    # intermediate compactions move whole levels: jobs sourced at levels
    # 1..max-3 consume at least as many input SSTs as a leveled single
    # pick ever would, and some are genuinely wide (> pick_batch inputs)
    mid_jobs = [j for j in sim.job_log
                if j.kind == "compact" and 1 <= j.level < cfg.max_levels - 2]
    assert mid_jobs, "expected intermediate-level wholesale compactions"
    assert max(j.n_in_ssts for j in mid_jobs) > 1


def test_policies_live_outside_the_mechanism():
    """No policy may be special-cased by the engine, and no policy may
    reach past the contract surface — enforced by the same layering
    rules the `repro-lint` CI gate runs (L101..L106), so this test and
    the lint can never disagree."""
    from repro.analysis import analyze_repo

    findings = analyze_repo(families=("layering",))
    assert findings == [], "\n".join(f.format() for f in findings)


# -------------------------------------------------------- paranoid_checks
def test_paranoid_checks_wired_into_drain_jobs(monkeypatch):
    calls = {"n": 0}
    orig = LSMTree.check_invariants

    def counting(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(LSMTree, "check_invariants", counting)
    cfg = get_policy("vlsm").default_config(scale=SCALE)
    assert cfg.paranoid_checks  # conftest turns the env default on
    _grow(cfg, 3, n_ops=3000, with_reads=False)
    assert calls["n"] > 0, "drain_jobs never ran the invariant sweep"

    calls["n"] = 0
    _grow(cfg.with_(paranoid_checks=False), 3, n_ops=3000, with_reads=False)
    assert calls["n"] == 0, "paranoid_checks=False must skip the sweep"

"""LevelIndex manifest tests: backend parity (numpy / jnp / pallas),
batched-GET equivalence with the scalar path, and mirror consistency."""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propshim import HealthCheck, given, settings, st

from repro.core import DeviceModel, LSMConfig, Simulator
from repro.core import level_index
from repro.core.level_index import (LevelIndex, bloom_false_positives,
                                    bloom_seed_for_uid)
from repro.core.sst import SST, overlapping

CFG = LSMConfig.vlsm_default(scale=1 << 16)


def _mk_level(rng, n_ssts, keys_per=8):
    """A sorted, pairwise-disjoint level of n_ssts SSTs with random gaps."""
    out = []
    base = 0
    for _ in range(n_ssts):
        base += int(rng.integers(1, 50))
        ks = np.sort(rng.choice(np.arange(base, base + 200), size=keys_per,
                                replace=False)).astype(np.int64)
        out.append(SST(ks, np.zeros(keys_per, np.int64), 100))
        base = int(ks[-1]) + 1
    return out


def _queries(rng, n, hi_key):
    lo = rng.integers(-5, hi_key + 5, size=n).astype(np.int64)
    width = rng.integers(0, 60, size=n).astype(np.int64)
    return lo, lo + width


@pytest.mark.parametrize("n_ssts", [0, 1, 7, 64])
def test_backends_agree_on_overlap_queries(n_ssts):
    """numpy / jnp / pallas LevelIndex queries agree on random fence sets,
    including empty and single-SST levels."""
    rng = np.random.default_rng(42 + n_ssts)
    ssts = _mk_level(rng, n_ssts)
    idx = LevelIndex(2)
    idx.refresh(1, ssts)
    hi_key = int(ssts[-1].largest) if ssts else 100
    lo, hi = _queries(rng, 40, hi_key)
    ref = None
    for backend in ("numpy", "jnp", "pallas"):
        level_index.set_backend(backend)
        try:
            got = (*idx.overlap_ranges(1, lo, hi),
                   idx.overlap_counts(1, lo, hi))
        finally:
            level_index.set_backend("numpy")
        if ref is None:
            ref = got
        else:
            for a, b in zip(ref, got):
                assert np.array_equal(a, b), f"{backend} differs from numpy"
    # and the numpy answer matches the list-walking oracle
    starts, ends, counts = ref
    for i in range(lo.shape[0]):
        want = overlapping(ssts, int(lo[i]), int(hi[i]))
        got_slice = ssts[int(starts[i]):int(ends[i])]
        assert got_slice == want
        assert int(counts[i]) == len(want)


def test_overlap_bytes_matches_bruteforce():
    rng = np.random.default_rng(3)
    src = _mk_level(rng, 12)
    dst = _mk_level(rng, 30)
    idx = LevelIndex(3)
    idx.refresh(1, src)
    idx.refresh(2, dst)
    ob = idx.overlap_bytes(1, 2)
    for i, s in enumerate(src):
        want = sum(d.size for d in overlapping(dst, s.smallest, s.largest))
        assert int(ob[i]) == want


def _build_tree(seed, n_ops=2500, cfg=CFG):
    rng = np.random.default_rng(seed)
    sim = Simulator(cfg, DeviceModel.scaled(1 / 1024))
    keys = rng.integers(0, 800, size=n_ops).astype(np.int64)
    sim.run(np.zeros(n_ops, np.uint8), keys,
            np.arange(n_ops, dtype=np.float64) / 1e4)
    return sim.trees[0]


@given(st.integers(0, 2**32))
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_get_batch_equals_scalar_get(seed):
    """Property: get_batch == looped scalar get — seqs, reads AND probed."""
    tree = _build_tree(seed)
    rng = np.random.default_rng(seed + 1)
    queries = np.concatenate([
        rng.integers(0, 800, size=300),       # mostly hits
        rng.integers(10**6, 10**9, size=100),  # misses
    ]).astype(np.int64)
    b_seqs, b_reads, b_probed = tree.get_batch(queries)
    for i, k in enumerate(queries.tolist()):
        seq, reads, probed = tree.get(k)
        assert (seq if seq is not None else -1) == int(b_seqs[i])
        assert reads == int(b_reads[i])
        assert probed == int(b_probed[i])


def test_get_batch_pallas_backend_drop_in():
    """The pallas fence-rank kernel is a drop-in for the lookup path."""
    tree = _build_tree(7, n_ops=1500)
    rng = np.random.default_rng(8)
    queries = np.concatenate([rng.integers(0, 800, size=128),
                              rng.integers(10**6, 10**9, size=64)]
                             ).astype(np.int64)
    ref = tree.get_batch(queries)
    for backend in ("jnp", "pallas"):
        level_index.set_backend(backend)
        try:
            got = tree.get_batch(queries)
        finally:
            level_index.set_backend("numpy")
        for a, b in zip(ref, got):
            assert np.array_equal(a, b), f"{backend} lookup differs"


def test_per_store_index_backend_config():
    """LSMConfig.index_backend pins one store's manifest queries to an
    array backend regardless of the module-level switch."""
    cfg = CFG.with_(index_backend="jnp")
    tree = _build_tree(5, n_ops=1200, cfg=cfg)
    assert tree.index.backend == "jnp"
    ref_tree = _build_tree(5, n_ops=1200, cfg=CFG)
    rng = np.random.default_rng(6)
    queries = rng.integers(0, 800, size=200).astype(np.int64)
    got = tree.get_batch(queries)
    want = ref_tree.get_batch(queries)
    # NOTE: uids differ between the two trees (global counter), so bloom
    # false positives may differ — compare the found seqs only.
    assert np.array_equal(got[0], want[0])


def test_index_stays_in_lockstep_with_levels():
    """Incremental maintenance (flush, splice, uid-removal) never drifts
    from the SST lists, across every registered policy."""
    from repro.core.policies import default_configs
    for cfg in default_configs(scale=1 << 16).values():
        tree = _build_tree(11, n_ops=3000, cfg=cfg)
        tree.index.check_against(tree.levels)


def test_bloom_seed_matches_scalar_hash():
    keys = np.array([5, 12345, 2**47 + 3], np.int64)
    uid = 917
    want = [((int(k) * 0x9E3779B97F4A7C15 + uid * 0xBF58476D1CE4E5B9)
             & 0xFFFFFFFF) / 0xFFFFFFFF < 0.5 for k in keys]
    got = bloom_false_positives(keys, bloom_seed_for_uid(uid), 0.5)
    assert got.tolist() == want


def test_memtable_get_batch_matches_scalar():
    from repro.core.memtable import Memtable
    mt = Memtable(capacity_bytes=10_000, kv_size=100)
    mt.put_batch(np.array([5, 3, 5, 9]), np.array([1, 2, 3, 4]))
    out = mt.get_batch(np.array([5, 3, 4, 9], np.int64))
    assert out.tolist() == [3, 2, -1, 4]
    mt.put_batch(np.array([4]), np.array([5]))   # cache must invalidate
    assert mt.get_batch(np.array([4], np.int64)).tolist() == [5]

"""Shared benchmark helpers: scaled configs, rate calibration, CSV rows.

All KV benchmarks run at data scale λ = SCALE/64MiB with the matched
device model (DeviceModel.scaled) — see DESIGN.md's hardware-adaptation
table.  "SST size" knobs are expressed in *paper-equivalent* MB (8 MB
paper SST ↦ SCALE/8 bytes here).
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import numpy as np  # noqa: E402

from repro.bench_kv import (make_load_a, make_run_a, make_run_b, make_run_c,  # noqa: E402
                            make_run_d, run_ycsb, sustainable_throughput)
from repro.core import DeviceModel, LSMConfig, Policy  # noqa: E402

SCALE = 1 << 18           # "64 MB" ≙ 256 KiB;  λ = 1/256
PAPER_MB = 64             # what SCALE corresponds to


def sst_bytes(paper_mb: float) -> int:
    """Paper-equivalent SST size -> scaled bytes."""
    return max(4096, int(SCALE * paper_mb / PAPER_MB))


def vlsm_cfg(sst_mb: float = 8, phi: int = 32) -> LSMConfig:
    sst = sst_bytes(sst_mb)
    return LSMConfig(memtable_size=sst, sst_size=sst, l0_max_ssts=4,
                     policy=Policy.VLSM, growth_factor=8, phi=phi)


def rocksdb_cfg(sst_mb: float = 64, debt: float = 0.25) -> LSMConfig:
    sst = sst_bytes(sst_mb)
    return LSMConfig(memtable_size=sst, sst_size=sst, l0_max_ssts=4,
                     policy=Policy.ROCKSDB, debt_factor=debt, growth_factor=8)


def rocksdb_io_cfg(sst_mb: float = 64) -> LSMConfig:
    return rocksdb_cfg(sst_mb).with_(policy=Policy.ROCKSDB_IO, debt_factor=0.0)


def adoc_cfg(sst_mb: float = 64) -> LSMConfig:
    return rocksdb_cfg(sst_mb).with_(policy=Policy.ADOC, debt_factor=1.0)


def lsmi_cfg(sst_mb: float = 8) -> LSMConfig:
    sst = sst_bytes(sst_mb)
    return LSMConfig(memtable_size=sst, sst_size=sst, l0_max_ssts=4,
                     policy=Policy.LSMI, growth_factor=8)


_SUS_CACHE: dict = {}


def sus(cfg: LSMConfig, n: int = 50_000) -> float:
    key = (cfg.policy, cfg.sst_size, cfg.phi, cfg.debt_factor, n)
    if key not in _SUS_CACHE:
        _SUS_CACHE[key] = sustainable_throughput(cfg, make_load_a(n),
                                                 scale=SCALE)
    return _SUS_CACHE[key]


def load_at_fraction(cfg: LSMConfig, frac: float = 0.6, n: int = 50_000):
    return run_ycsb(cfg, make_load_a(n), rate=frac * sus(cfg, n), scale=SCALE)


ROWS: list[dict] = []

_last_emit_t = [time.perf_counter()]


def emit(name: str, value, derived: str = "") -> None:
    """Record one result row (and print it as CSV).

    Every row carries ``wall_clock_s`` — the wall time since the previous
    ``emit`` (since import for the first row): roughly what the
    measurement that produced the row cost.  Rows accumulate in ``ROWS``
    for ``--json`` persistence.
    """
    now = time.perf_counter()
    wall, _last_emit_t[0] = now - _last_emit_t[0], now
    row = {"name": name, "value": value, "derived": derived,
           "wall_clock_s": round(wall, 3)}
    # under REPRO_PARANOID_CHECKS=1 every row is validated against the
    # schema repro-lint extracts from this very literal (B6xx), so a
    # drifting emitter fails the smoke run, not just the linter
    from repro.analysis.schemas import CSV_FAMILY, paranoid_validate_rows
    paranoid_validate_rows([row], family=CSV_FAMILY)
    ROWS.append(row)
    print(f"{name},{value},{derived}", flush=True)

"""One function per paper figure/table (Figs 1-13, Table 1).

Each returns a list of CSV rows and prints them via common.emit.  Run all
with ``python -m benchmarks.run``; individual figures:
``python -m benchmarks.fig_benchmarks fig08``.
"""

from __future__ import annotations

import numpy as np

from .common import (SCALE, adoc_cfg, emit, load_at_fraction, lsmi_cfg,
                     rocksdb_cfg, rocksdb_io_cfg, sst_bytes, sus, vlsm_cfg)
from repro.bench_kv import (make_load_a, make_run_a, make_run_b, make_run_c,
                            make_run_d, run_ycsb, zipf_keys)
from repro.bench_kv.workloads import load_keys, pareto_keys
from repro.core import LSMConfig


# ---------------------------------------------------------------- Figure 1
def fig01_stall_timeline(n=60_000):
    """RocksDB throughput timeline + stall share under Load A (Fig 1a) and
    P99 vs load (Fig 1b)."""
    cfg = rocksdb_cfg()
    r = load_at_fraction(cfg, 0.95, n)
    centers, rate = r.sim.completions_timeline(bins=40)
    stall_share = r.sim.stall_total / max(r.sim.makespan, 1e-9)
    emit("fig01a.stall_share_pct", round(100 * stall_share, 1),
         "share of runtime spent write-stalled (paper: ~40%)")
    emit("fig01a.throughput_min_over_mean",
         round(float(rate.min() / max(rate.mean(), 1e-9)), 3),
         "dips to ~0 during stalls")
    for frac in (0.4, 0.6, 0.8, 0.95):
        rr = load_at_fraction(cfg, frac, n)
        emit(f"fig01b.p99_ms@{int(frac*100)}pct_load",
             round(rr.sim.p99 * 1e3, 1), "rocksdb P99 vs load")


# ---------------------------------------------------------------- Figure 2
def fig02_chains_rocksdb(n=50_000):
    """RocksDB chain width/length vs SST size (Fig 2)."""
    rows = []
    for sst_mb in (64, 32, 16, 8):
        cfg = rocksdb_io_cfg(sst_mb=64).with_(sst_size=sst_bytes(sst_mb))
        r = load_at_fraction(cfg, 0.7, n)
        st = r.sim.stats
        emit(f"fig02.width_mb@sst{sst_mb}",
             round(st.mean_chain_width / 1e6 * 256, 1),
             "paper-equivalent MB (x256 descale)")
        emit(f"fig02.length@sst{sst_mb}", round(st.mean_chain_length, 2), "")
        rows.append((sst_mb, st.mean_chain_width, st.mean_chain_length))
    return rows


# ---------------------------------------------------------------- Figure 4
def fig04_ioamp_notiering(n=50_000):
    """(a) LSMi (no tiering, fixed SSTs): single-SST L0->L1 with L1=L0 size
    explodes I/O amp as SSTs shrink; (b) levels grow with small SSTs."""
    for sst_mb in (64, 8):
        cfg = lsmi_cfg(sst_mb=sst_mb)
        r = load_at_fraction(cfg, 0.5, n)
        emit(f"fig04a.lsmi_ioamp@sst{sst_mb}", round(r.sim.stats.io_amp, 1),
             "no-tiering naive: amp grows as SST shrinks")
    v = load_at_fraction(vlsm_cfg(sst_mb=8), 0.5, n)
    emit("fig04.vlsm_ioamp@sst8", round(v.sim.stats.io_amp, 1),
         "vLSM: small SSTs + phi + vSSTs")


# ---------------------------------------------------------------- Figure 7
def fig07_stalls(n=60_000):
    """Write stalls (left), max stall (middle), I/O amp (right), per
    policy; vLSM across SST sizes (Fig 7)."""
    systems = {
        "rocksdb": rocksdb_cfg(), "rocksdb_io": rocksdb_io_cfg(),
        "adoc": adoc_cfg(),
        "vlsm_sst8": vlsm_cfg(8), "vlsm_sst16": vlsm_cfg(16),
        "vlsm_sst32": vlsm_cfg(32), "vlsm_sst64": vlsm_cfg(64, phi=4),
    }
    out = {}
    for name, cfg in systems.items():
        r = load_at_fraction(cfg, 0.6, n)
        out[name] = r
        emit(f"fig07.stall_total_s.{name}", round(r.sim.stall_total, 3), "")
        emit(f"fig07.stall_max_s.{name}", round(r.sim.stall_max, 3), "")
        emit(f"fig07.io_amp.{name}", round(r.sim.stats.io_amp, 1), "")
    red = 1 - out["vlsm_sst8"].sim.stall_total / max(
        out["rocksdb_io"].sim.stall_total, 1e-9)
    emit("fig07.vlsm_stall_reduction_pct", round(100 * red, 1),
         "paper: up to 60%")
    return out


# ---------------------------------------------------------------- Figure 8
def fig08_p99_vs_rate(n=50_000):
    """P99 vs request rate for vLSM (8MB) and RocksDB (Fig 8)."""
    cfg_v, cfg_r = vlsm_cfg(8), rocksdb_cfg()
    for frac in (0.3, 0.5, 0.7, 0.9):
        rv = load_at_fraction(cfg_v, frac, n)
        rr = load_at_fraction(cfg_r, frac, n)
        emit(f"fig08.p99_ms@{int(frac*100)}pct.vlsm",
             round(rv.sim.p99 * 1e3, 2), "")
        emit(f"fig08.p99_ms@{int(frac*100)}pct.rocksdb",
             round(rr.sim.p99 * 1e3, 2), "")


# ---------------------------------------------------------------- Figure 9
def fig09_chains_vlsm(n=50_000):
    """vLSM chain width/length vs SST size (Fig 9); paper: width down to
    ~32 MB at 4 MB SSTs (=320x below RocksDB's 10 GB)."""
    for sst_mb in (64, 32, 16, 8, 4):
        # keep L2 at the RocksDB-equivalent 2 GB: phi = 2048 / (8*sst)
        phi = max(4, int(2048 / (8 * sst_mb)))
        cfg = vlsm_cfg(sst_mb, phi=phi)
        r = load_at_fraction(cfg, 0.5, n)
        st = r.sim.stats
        emit(f"fig09.width_mb@sst{sst_mb}",
             round(st.mean_chain_width / 1e6 * 256, 1),
             "paper-equivalent MB")
        emit(f"fig09.length@sst{sst_mb}", round(st.mean_chain_length, 2), "")


# --------------------------------------------------------------- Figure 10
def fig10_regions(n=80_000):
    """Tail latency + throughput vs number of regions (Fig 10)."""
    for regions in (1, 4, 16):
        for name, cfg in (("vlsm", vlsm_cfg(8)), ("rocksdb", rocksdb_cfg())):
            spec = make_load_a(n)
            rate = 0.6 * sus(cfg, n)
            r = run_ycsb(cfg, spec, rate=rate, n_regions=regions, scale=SCALE)
            emit(f"fig10.p99_ms.{name}@r{regions}",
                 round(r.sim.p99 * 1e3, 2), "")
            emit(f"fig10.mean_chain_mb.{name}@r{regions}",
                 round(r.sim.stats.mean_chain_width / 1e6 * 256, 1),
                 "paper-equivalent MB")


# --------------------------------------------------------------- Figure 11
def fig11_cdf(n=60_000):
    """Load-A latency CDF percentiles for RocksDB-IO vs vLSM (Fig 11)."""
    rv = load_at_fraction(vlsm_cfg(8), 0.6, n)
    rr = load_at_fraction(rocksdb_io_cfg(), 0.6, n)
    for q in (50, 90, 99, 99.9):
        emit(f"fig11.p{q}_ms.vlsm", round(rv.sim.pct(q) * 1e3, 3), "")
        emit(f"fig11.p{q}_ms.rocksdb_io", round(rr.sim.pct(q) * 1e3, 3), "")


# --------------------------------------------------------------- Figure 12
def fig12_ycsb_sweep(n_load=50_000, n_run=30_000):
    """All YCSB workloads: P99 (read/write), throughput, CPU proxy
    (Figs 6 & 12)."""
    pop = load_keys(n_load)
    runs = {
        "run_a": make_run_a(pop, n_run),
        "run_b": make_run_b(pop, n_run),
        "run_c": make_run_c(pop, n_run),
        "run_d": make_run_d(pop, n_run),
    }
    for sys_name, cfg in (("vlsm8", vlsm_cfg(8)),
                          ("rocksdb_io", rocksdb_io_cfg()),
                          ("adoc", adoc_cfg())):
        rate = 0.6 * sus(cfg, n_load)
        for wname, spec in runs.items():
            r = run_ycsb(cfg, spec, rate=rate, scale=SCALE, preload=pop)
            emit(f"fig12.{wname}.p99_write_ms.{sys_name}",
                 round(r.sim.pct(99, op=0) * 1e3, 3), "")
            emit(f"fig12.{wname}.p99_read_ms.{sys_name}",
                 round(r.sim.pct(99, op=1) * 1e3, 3), "")
            emit(f"fig12.{wname}.cycles_op.{sys_name}",
                 round(r.cycles_per_op(), 0), "CPU proxy")


# ------------------------------------------------------------- scan tails
def scan_tails(n_load=50_000, n_run=20_000):
    """YCSB-E (95% scan / 5% insert) while a writer streams at a fixed
    rate — the read-tail story (paper §6.3) extended to range scans via
    the db_bench seekrandom-while-writing methodology."""
    from repro.bench_kv.db_bench import seekrandom
    for sys_name, cfg in (("vlsm8", vlsm_cfg(8)),
                          ("rocksdb", rocksdb_cfg()),
                          ("rocksdb_io", rocksdb_io_cfg()),
                          ("adoc", adoc_cfg()),
                          ("lsmi", lsmi_cfg())):
        row = seekrandom(cfg, n_run, n_load, scale=SCALE)
        emit(f"scan_e.p99_scan_ms.{sys_name}", row["p99_scan_ms"], "")
        emit(f"scan_e.p50_scan_ms.{sys_name}", row["p50_scan_ms"], "")
        emit(f"scan_e.files_per_scan.{sys_name}", row["scan_files_per_op"],
             "seek fan-out (L0 + one per level)")


# --------------------------------------------------------------- Figure 13
def fig13_phi_sensitivity(n=50_000):
    """I/O amp + good-vSST fraction vs Φ (Fig 13 a,b) and key
    distributions (Fig 13c)."""
    for phi, sst_mb in ((4, 64), (8, 32), (16, 16), (32, 8), (64, 4)):
        cfg = vlsm_cfg(sst_mb, phi=phi)
        r = load_at_fraction(cfg, 0.5, n)
        st = r.sim.stats
        tot = max(1, st.vssts_good + st.vssts_poor)
        emit(f"fig13a.io_amp@phi{phi}", round(st.io_amp, 1), "")
        emit(f"fig13b.good_vsst_pct@phi{phi}",
             round(100 * st.vssts_good / tot, 1),
             "paper: ~90% @phi32, ~6% @phi64")
    # distributions (13c): uniform vs zipfian vs pareto at phi=32
    pop = load_keys(n)
    cfg = vlsm_cfg(8)
    rate = 0.5 * sus(cfg, n)
    for dist, keys in (("uniform", pop),
                       ("zipfian", zipf_keys(pop, n)),
                       ("pareto", pareto_keys(pop, n))):
        spec = make_load_a(n)
        spec.keys = keys
        r = run_ycsb(cfg, spec, rate=rate, scale=SCALE)
        emit(f"fig13c.io_amp.{dist}", round(r.sim.stats.io_amp, 1),
             "vLSM amp stable across key distributions")


# ----------------------------------------------------------------- Table 1
def tab01_sst_size(n=50_000):
    """vLSM sensitivity to very small SSTs (Table 1): 8/4/2 MB."""
    for sst_mb in (8, 4, 2):
        cfg = vlsm_cfg(sst_mb, phi=32)
        s = sus(cfg, n)
        r = load_at_fraction(cfg, 0.6, n)
        emit(f"tab01.p99_ms@sst{sst_mb}", round(r.sim.p99 * 1e3, 2), "")
        emit(f"tab01.kops@sst{sst_mb}", round(s / 1e3, 2),
             "sustainable throughput")
        emit(f"tab01.kcycles_op@sst{sst_mb}",
             round(r.cycles_per_op() / 1e3, 1),
             "CPU proxy rises as SSTs shrink")


ALL = {
    "fig01": fig01_stall_timeline,
    "fig02": fig02_chains_rocksdb,
    "fig04": fig04_ioamp_notiering,
    "fig07": fig07_stalls,
    "fig08": fig08_p99_vs_rate,
    "fig09": fig09_chains_vlsm,
    "fig10": fig10_regions,
    "fig11": fig11_cdf,
    "fig12": fig12_ycsb_sweep,
    "fig13": fig13_phi_sensitivity,
    "scan_e": scan_tails,
    "tab01": tab01_sst_size,
}


if __name__ == "__main__":
    import sys
    names = sys.argv[1:] or list(ALL)
    for n in names:
        ALL[n]()

"""Serving-integration benchmark: the serve_sweep family's CSV companion.

Drives the pinned multi-tenant open-loop scenario
(``repro.bench_kv.db_bench.make_serve_spec``) through the DES with the
admission controller off and on, and emits the knee-side numbers:
goodput, shed fraction, and the high-priority tenant's P99.9 past the
saturation knee.  The paper's Fig 1 pathology (multi-second write
stalls landing on foreground requests) shows up here as the open-loop
collapse of the admission-off curve; the controller buys the
priority-0 tenant a bounded tail by shedding low-priority work
(``shed_frac`` > 0) instead of queueing it.

Full per-factor rows — every policy, every load factor, per-tenant
ledgers — live in db_bench's ``serve_sweep`` output
(``--bench serve_sweep``); see docs/benchmarks.md for the row schema.
"""

from __future__ import annotations

from .common import emit


def bench_serving_tail(n: int = 60_000):
    # quick sizes for --full too: the CSV row is a smoke-level summary,
    # the real sweep is db_bench's (n retained for run.py's --full call)
    from repro.bench_kv.db_bench import serve_sweep_bench
    full = n > 60_000
    rows = serve_sweep_bench(
        ["vlsm", "rocksdb"],
        duration_s=4.0 if full else 1.5,
        population=8_000 if full else 3_000,
        factors=(1.0, 3.0))
    for r in rows:
        prio = next(t for t in r["per_tenant"] if t["priority"] == 0)
        emit(f"serve.goodput_ops_s.{r['policy']}.adm_{r['admission']}"
             f".x{r['load_factor']}",
             r["goodput_ops_s"],
             f"shed={r['shed_frac']};offered={r['offered_ops_s']}")
        emit(f"serve.prio_p999_ms.{r['policy']}.adm_{r['admission']}"
             f".x{r['load_factor']}",
             prio["p999_ms"],
             f"slo_viol={prio['slo_violation_frac']}")


if __name__ == "__main__":
    bench_serving_tail()

"""Serving-integration benchmark: prefix-cache index tail latency under
insert churn, vLSM policy vs RocksDB-style tiering.

Every admitted prompt inserts its block-hash chain into the prefix-cache
index.  We drive that insert stream through the DES for both index
policies — the paper's Fig 1 pathology (multi-second write stalls from
tiering chains) would land directly on request admission latency; vLSM's
narrow chains keep the admission path flat.
"""

from __future__ import annotations

import numpy as np

from .common import SCALE, emit
from repro.bench_kv import make_load_a, run_ycsb, sustainable_throughput
from repro.core import LSMConfig


def bench_serving_tail(n: int = 60_000):
    # key stream = 64-bit block hashes (high-entropy uniform, like
    # PrefixCache._hash_tokens output)
    spec = make_load_a(n)
    for name, cfg in (
            ("vlsm", LSMConfig.vlsm_default(scale=SCALE).with_(kv_size=64)),
            ("rocksdb", LSMConfig.rocksdb_default(scale=SCALE).with_(kv_size=64))):
        sus = sustainable_throughput(cfg, spec, scale=SCALE)
        r = run_ycsb(cfg, spec, rate=0.6 * sus, scale=SCALE)
        emit(f"serving.index_p99_ms.{name}", round(r.sim.p99 * 1e3, 3),
             "prefix-cache insert admission tail")
        emit(f"serving.index_stall_max_s.{name}", round(r.sim.stall_max, 3),
             "")


if __name__ == "__main__":
    bench_serving_tail()

"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,value,derived`` CSV — one section per paper table/figure
(Figs 1-13, Table 1), plus the distributed-layer wire benchmark.  Use
``--full`` for the larger op counts, ``--only fig08,fig13`` to select.
The roofline table is separate: ``python -m benchmarks.roofline``.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure ids (default: all)")
    ap.add_argument("--full", action="store_true",
                    help="larger op counts (slower, smoother tails)")
    ap.add_argument("--json", default=None,
                    help="also persist every emitted row as JSON here")
    ap.add_argument("--policy", default="all",
                    help="compaction policy name(s) for the db_bench "
                         "section, comma-separated, or 'all' — resolved "
                         "from the repro.core.policies registry")
    ap.add_argument("--seed", type=int, default=7,
                    help="base RNG seed for the db_bench-backed sections")
    ap.add_argument("--workers", type=int, default=1,
                    help="sweep-executor fork-pool size for the "
                         "fleet_sweep section (1 = in-process; rows are "
                         "byte-identical at every worker count)")
    ap.add_argument("--sanitize", action="store_true",
                    help="run every simulation under the DES schedule "
                         "sanitizer (REPRO_SANITIZE=1; see "
                         "docs/analysis.md) — slower, but any scheduling "
                         "invariant violation aborts at first divergence")
    args = ap.parse_args()
    if args.sanitize:
        import os
        os.environ["REPRO_SANITIZE"] = "1"

    from . import fig_benchmarks as fb
    names = args.only.split(",") if args.only else list(fb.ALL)
    t0 = time.time()
    print("name,value,derived")
    for name in names:
        fn = fb.ALL[name]
        t1 = time.time()
        if args.full:
            try:
                fn(120_000)          # larger op count where supported
            except TypeError:
                fn()
        else:
            fn()
        print(f"# {name} done in {time.time()-t1:.1f}s", flush=True)
    # db_bench (paper §5: amplification-only, Meta-style population).
    # Policies resolve from the registry: --policy vlsm,lazy or 'all'.
    try:
        from repro.bench_kv.db_bench import chain_report, fill_sim, fillrandom
        from repro.core.policies import get_policy, resolve_names
        from .common import SCALE, emit
        chosen = resolve_names(args.policy)
        for dist in ("uniform", "pareto"):
            for nm in chosen:
                cfg = get_policy(nm).default_config(scale=SCALE)
                run = fill_sim(cfg, 60_000, dist, SCALE, args.seed)
                row = fillrandom(cfg, 60_000, dist=dist, scale=SCALE,
                                 seed=args.seed, run=run)
                emit(f"db_bench.{dist}.io_amp.{nm}", row["io_amp"],
                     f"levels={row['levels_filled']}")
                if dist != "uniform":
                    continue
                # chain observatory off the SAME simulation (paper §3;
                # full distributions live in db_bench's chain_report
                # rows — see docs/benchmarks.md)
                crow = chain_report(cfg, 60_000, scale=SCALE,
                                    seed=args.seed, run=run)
                emit(f"db_bench.chain.mean_width_ssts.{nm}",
                     crow.get("mean_width_ssts", 0.0),
                     f"eff_len={crow.get('effective_length', 0.0)}")
    except Exception as e:  # pragma: no cover
        print(f"# db_bench skipped: {e}")
    # sharded fleet: P99 vs shard count at a fixed aggregate rate, plus
    # the Zipf hot-shard interference point (full distributions live in
    # db_bench's shard_sweep rows — see docs/benchmarks.md)
    try:
        from repro.bench_kv.db_bench import (HOT_RATE, HOT_SHARDS,
                                             SHARD_COUNTS, SWEEP_RATE,
                                             shard_sweep)
        from repro.core.policies import get_policy, resolve_names
        from .common import SCALE, emit
        for nm in resolve_names(args.policy):
            for k in SHARD_COUNTS:
                cfg = get_policy(nm).default_config(scale=SCALE) \
                    .with_(n_shards=k)
                row = shard_sweep(cfg, 20_000, 30_000, scale=SCALE,
                                  rate=SWEEP_RATE, seed=args.seed)
                emit(f"db_bench.shard_sweep.p99_get_ms.{nm}.x{k}",
                     row["p99_get_ms"], f"p999={row['p999_get_ms']}")
            cfg = get_policy(nm).default_config(scale=SCALE) \
                .with_(n_shards=HOT_SHARDS, shard_router="range")
            row = shard_sweep(cfg, 20_000, 30_000, dist="zipf_ranked",
                              scale=SCALE, rate=HOT_RATE, seed=args.seed)
            emit(f"db_bench.shard_hot.p99_get_ms.{nm}.x{HOT_SHARDS}",
                 row["p99_get_ms"],
                 f"hot_frac={row['hot_shard_frac']};"
                 f"stall_s={row['stall_total_s']}")
    except Exception as e:  # pragma: no cover
        print(f"# shard_sweep skipped: {e}")
    # batched fleet engine: the policy × shard × rate matrix as one
    # structural replay per point + batched Lindley accounting, with the
    # serial heap loop as timed baseline and parity oracle (full-size
    # matrix lives in db_bench's fleet_sweep rows — see docs/benchmarks.md)
    try:
        from repro.bench_kv.db_bench import (FLEET_RATES_QUICK,
                                             fleet_sweep_bench)
        from repro.core.policies import resolve_names
        from .common import SCALE, emit
        frows = fleet_sweep_bench(resolve_names(args.policy), 6_000, 8_000,
                                  scale=SCALE, rates=FLEET_RATES_QUICK,
                                  shard_counts=(1, 4), seed=args.seed,
                                  workers=args.workers)
        summary = frows[-1]
        emit("db_bench.fleet_sweep.speedup", summary["speedup"],
             f"runs={summary['runs']};"
             f"fleet_wall_s={summary['fleet_wall_s']}")
        emit("db_bench.fleet_sweep.parity_max_abs_latency_s",
             summary["parity_max_abs_latency_s"],
             f"stalls_equal={summary['parity_stalls_equal']}")
        top_rate = max(r["rate_ops_s"] for r in frows[:-1])
        for row in frows[:-1]:
            if row["rate_ops_s"] == top_rate:
                emit(f"db_bench.fleet_sweep.p99_get_ms."
                     f"{row['policy']}.x{row['n_shards']}",
                     row["p99_get_ms"], f"rate={row['rate_ops_s']}")
    except Exception as e:  # pragma: no cover
        print(f"# fleet_sweep skipped: {e}")
    # open-loop multi-tenant serving: goodput/shed/priority-tail numbers
    # at and past the saturation knee, admission off vs on (full
    # per-factor rows live in db_bench's serve_sweep output — see
    # docs/benchmarks.md)
    try:
        from .serving_tail import bench_serving_tail
        bench_serving_tail(120_000 if args.full else 60_000)
    except Exception as e:  # pragma: no cover
        print(f"# serve_sweep skipped: {e}")
    # distributed wire benchmark (fast, lowering only)
    try:
        from .compression_wire import bench_wire
        bench_wire()
    except Exception as e:  # pragma: no cover
        print(f"# compression_wire skipped: {e}")
    print(f"# total {time.time()-t0:.1f}s")
    if args.json:
        import json
        from pathlib import Path

        from repro.analysis.schemas import (CSV_FAMILY,
                                            paranoid_validate_rows)

        from .common import ROWS
        # schema gate (no-op unless REPRO_PARANOID_CHECKS=1): rows
        # must match the shape repro-lint extracts from common.emit
        paranoid_validate_rows(ROWS, family=CSV_FAMILY)
        Path(args.json).write_text(json.dumps(ROWS, indent=1))
        print(f"# wrote {args.json} ({len(ROWS)} rows)")


if __name__ == "__main__":
    main()

"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,value,derived`` CSV — one section per paper table/figure
(Figs 1-13, Table 1), plus the distributed-layer wire benchmark.  Use
``--full`` for the larger op counts, ``--only fig08,fig13`` to select.
The roofline table is separate: ``python -m benchmarks.roofline``.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure ids (default: all)")
    ap.add_argument("--full", action="store_true",
                    help="larger op counts (slower, smoother tails)")
    ap.add_argument("--json", default=None,
                    help="also persist every emitted row as JSON here")
    ap.add_argument("--policy", default="all",
                    help="compaction policy name(s) for the db_bench "
                         "section, comma-separated, or 'all' — resolved "
                         "from the repro.core.policies registry")
    args = ap.parse_args()

    from . import fig_benchmarks as fb
    names = args.only.split(",") if args.only else list(fb.ALL)
    t0 = time.time()
    print("name,value,derived")
    for name in names:
        fn = fb.ALL[name]
        t1 = time.time()
        if args.full:
            try:
                fn(120_000)          # larger op count where supported
            except TypeError:
                fn()
        else:
            fn()
        print(f"# {name} done in {time.time()-t1:.1f}s", flush=True)
    # db_bench (paper §5: amplification-only, Meta-style population).
    # Policies resolve from the registry: --policy vlsm,lazy or 'all'.
    try:
        from repro.bench_kv.db_bench import chain_report, fill_sim, fillrandom
        from repro.core.policies import get_policy, resolve_names
        from .common import SCALE, emit
        chosen = resolve_names(args.policy)
        for dist in ("uniform", "pareto"):
            for nm in chosen:
                cfg = get_policy(nm).default_config(scale=SCALE)
                run = fill_sim(cfg, 60_000, dist, SCALE)
                row = fillrandom(cfg, 60_000, dist=dist, scale=SCALE,
                                 run=run)
                emit(f"db_bench.{dist}.io_amp.{nm}", row["io_amp"],
                     f"levels={row['levels_filled']}")
                if dist != "uniform":
                    continue
                # chain observatory off the SAME simulation (paper §3;
                # full distributions live in db_bench's chain_report
                # rows — see docs/benchmarks.md)
                crow = chain_report(cfg, 60_000, scale=SCALE, run=run)
                emit(f"db_bench.chain.mean_width_ssts.{nm}",
                     crow.get("mean_width_ssts", 0.0),
                     f"eff_len={crow.get('effective_length', 0.0)}")
    except Exception as e:  # pragma: no cover
        print(f"# db_bench skipped: {e}")
    # serving-integration tail benchmark
    try:
        from .serving_tail import bench_serving_tail
        bench_serving_tail()
    except Exception as e:  # pragma: no cover
        print(f"# serving_tail skipped: {e}")
    # distributed wire benchmark (fast, lowering only)
    try:
        from .compression_wire import bench_wire
        bench_wire()
    except Exception as e:  # pragma: no cover
        print(f"# compression_wire skipped: {e}")
    print(f"# total {time.time()-t0:.1f}s")
    if args.json:
        import json
        from pathlib import Path

        from .common import ROWS
        Path(args.json).write_text(json.dumps(ROWS, indent=1))
        print(f"# wrote {args.json} ({len(ROWS)} rows)")


if __name__ == "__main__":
    main()

"""Assemble the §Roofline table from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline [--md]

Per (arch × shape × mesh): the three roofline terms (seconds), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, per-device memory.
v5e constants: 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(variant: str = "baseline") -> list[dict]:
    cells = []
    for p in sorted(RESULTS.glob(f"*__{variant}.json")):
        d = json.loads(p.read_text())
        d["_file"] = p.name
        cells.append(d)
    return cells


def fmt_row(d: dict) -> str:
    if "skipped" in d:
        a, s, m, _v = d["_file"][:-5].split("__")
        return f"| {a} | {s} | {m} | SKIP | — | — | — | — | — |"
    if "error" in d:
        a, s, m, _v = d["_file"][:-5].split("__")
        return f"| {a} | {s} | {m} | ERROR | — | — | — | — | — |"
    r = d["roofline"]
    mem = d.get("memory", {})
    peak = mem.get("peak_bytes") or mem.get("temp_bytes") or 0
    args = mem.get("argument_bytes", 0)
    ratio = d.get("useful_flops_ratio", 0.0)
    return ("| {arch} | {shape} | {mesh} | {tc:.3g} | {tm:.3g} | {tx:.3g} "
            "| {dom} | {ratio:.2f} | {mem:.1f} |").format(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tx=r["t_collective_s"],
        dom=r["dominant"], ratio=ratio, mem=(peak + args) / 2**30)


def main():
    cells = load_cells()
    single = [c for c in cells if c.get("mesh", "16x16") == "16x16"
              or "single" in c["_file"]]
    print("| arch | shape | mesh | t_compute(s) | t_memory(s) | "
          "t_collective(s) | dominant | useful_flops | mem/dev (GiB) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        print(fmt_row(c))
    ok = [c for c in cells if "roofline" in c]
    if ok:
        doms = {}
        for c in ok:
            doms[c["roofline"]["dominant"]] = doms.get(
                c["roofline"]["dominant"], 0) + 1
        print(f"\n# cells={len(cells)} compiled={len(ok)} dominant={doms}",
              file=sys.stderr)


if __name__ == "__main__":
    main()

"""Wire-bytes benchmark for int8 gradient compression (dry-run method
applied to a single collective): lower an fp32 psum and the int8
compressed_psum over a 4-device 'pod' axis and diff the parsed collective
bytes from the compiled HLO."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import emit

_CODE = """
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
import sys
sys.path.insert(0, %r)
from repro.distributed.compression import compressed_psum
from repro.launch.dryrun import collective_bytes

mesh = jax.make_mesh((4,), ("pod",))
x = jnp.zeros((1024, 1024), jnp.float32)          # 4 MiB payload

def plain(x):
    return jax.lax.psum(x, "pod")

def packed(x):
    return compressed_psum(x, "pod")

for name, fn in (("fp32", plain), ("int8", packed)):
    f = jax.jit(shard_map(fn, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          check_rep=False))
    txt = f.lower(x).compile().as_text()
    c = collective_bytes(txt)
    wire = sum(v for k, v in c.items() if k not in ("_count", "per_op_counts"))
    print(f"{name},{int(wire)}")
"""


def bench_wire() -> None:
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    out = subprocess.run([sys.executable, "-c",
                          textwrap.dedent(_CODE % src)],
                         capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        emit("compression.error", 1, out.stderr.strip()[-200:])
        return
    vals = dict(line.split(",") for line in out.stdout.strip().splitlines())
    fp32 = float(vals.get("fp32", 0))
    int8 = float(vals.get("int8", 1))
    emit("compression.fp32_wire_bytes", int(fp32), "psum of 4MiB fp32")
    emit("compression.int8_wire_bytes", int(int8), "compressed_psum")
    if int8 > 0:
        emit("compression.wire_reduction_x", round(fp32 / int8, 2),
             "cross-pod gradient traffic reduction")


if __name__ == "__main__":
    bench_wire()
